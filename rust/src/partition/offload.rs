//! Adaptive cross-device operator offloading (Sec. III-B1): a graph-based
//! search over the pre-partitioned segments that picks the optimal
//! assignment of contiguous segment runs to devices, minimizing end-to-end
//! latency under per-device memory budgets.
//!
//! Because pre-partitioning reduced the model to a *chain* of segments
//! with single-tensor frontiers, the optimal assignment is a shortest
//! path in a DAG of (segment-boundary, device) states — O(S·D²).
//!
//! The plan is consumed at two granularities (Fig. 6's plan → actuation
//! edge):
//!
//! - **Totals** (`latency_s`, `transfer_bytes`) price whole routes —
//!   [`OffloadPlan::route_weight`] seeds the shard router's full-remote
//!   priors.
//! - **Per-segment structure** ([`OffloadPlan::segment_runs`],
//!   [`OffloadPlan::split_cut`], with per-boundary frontier bytes from
//!   [`super::prepartition::PrePartition::frontier_bytes`]) survives
//!   into the serving path: a mid-chain plan actuates a *split route*
//!   (`crate::coordinator::ShardRouter`) that executes segments
//!   `0..cut` locally and ships the cut's frontier tensor per request —
//!   the Sec. III-B placement operating at serving time instead of being
//!   flattened to a single route prior. (Priority-lane requests are
//!   never split-routed; see the shard router's invariant.)

use crate::device::ResourceSnapshot;
use crate::graph::Graph;
use crate::profiler::{estimate_energy, estimate_latency};

use super::network::Topology;
use super::prepartition::PrePartition;

/// One device's share of the plan.
#[derive(Debug, Clone)]
pub struct Placement {
    pub device: String,
    /// Segment indices (contiguous) this device executes.
    pub segments: Vec<usize>,
}

/// A complete offloading plan with its predicted cost.
#[derive(Debug, Clone)]
pub struct OffloadPlan {
    pub placements: Vec<Placement>,
    pub latency_s: f64,
    pub energy_j: f64,
    /// Peak memory on the *local* (first) device.
    pub local_memory_bytes: f64,
    pub transfer_bytes: usize,
}

impl OffloadPlan {
    /// Plan that runs everything locally.
    pub fn local_only(device: &str, n_segments: usize, latency_s: f64, energy_j: f64, mem: f64) -> Self {
        OffloadPlan {
            placements: vec![Placement { device: device.into(), segments: (0..n_segments).collect() }],
            latency_s,
            energy_j,
            local_memory_bytes: mem,
            transfer_bytes: 0,
        }
    }

    pub fn is_local_only(&self) -> bool {
        self.placements.len() <= 1
    }

    /// Does any placement run segments on `device`?
    pub fn involves(&self, device: &str) -> bool {
        self.placements.iter().any(|p| p.device == device)
    }

    /// Plan → route weight for the serving layer's shard router: the
    /// plan-predicted end-to-end latency of serving one request through
    /// this assignment, for a `device` that participates in it; `None`
    /// when the plan does not route through the device (the router then
    /// treats the peer as plan-excluded until measurements say otherwise).
    pub fn route_weight(&self, device: &str) -> Option<f64> {
        self.involves(device).then_some(self.latency_s)
    }

    /// The plan's contiguous segment runs in execution order, as
    /// `(device, first_segment..one_past_last)` ranges — the Sec. III-B
    /// assignment at the granularity the serving layer streams at,
    /// instead of the `transfer_bytes`/`latency_s` totals.
    pub fn segment_runs(&self) -> Vec<(&str, std::ops::Range<usize>)> {
        self.placements
            .iter()
            .map(|p| {
                let first = p.segments.first().copied().unwrap_or(0);
                (p.device.as_str(), first..first + p.segments.len())
            })
            .collect()
    }

    /// Mid-chain split view: when the plan is exactly two contiguous
    /// runs — `head_device` executes segments `0..cut`, `tail_device`
    /// executes `cut..n` — returns `(head_device, tail_device, cut)`.
    /// This is the shape the shard router's segment streaming serves
    /// (head local, frontier shipped once, tail on the peer); the router
    /// checks the head against its own peer set, since the plan itself
    /// does not know which device is local. `None` for local-only plans,
    /// whole-chain remote plans (cut 0 is full-remote routing, not a
    /// split), and chains bouncing across three or more runs (streaming
    /// ships a single frontier per request).
    pub fn split_cut(&self) -> Option<(&str, &str, usize)> {
        if self.placements.len() != 2 {
            return None;
        }
        let (head, tail) = (&self.placements[0], &self.placements[1]);
        if head.device == tail.device || head.segments.first() != Some(&0) {
            return None;
        }
        Some((head.device.as_str(), tail.device.as_str(), head.segments.len()))
    }
}

/// Per-device execution rates used by the planner (derived from live
/// snapshots so the plan adapts to DVFS/contention on each peer).
#[derive(Debug, Clone)]
pub struct DeviceState {
    pub snap: ResourceSnapshot,
    /// Memory budget available for model weights + activations (bytes).
    pub mem_budget: f64,
}

/// Search the optimal contiguous assignment of segments to devices.
///
/// `graph` is the (possibly compressed) model; `pp` its pre-partition;
/// `devices[0]` is the local device where input data originates and where
/// the final output must return.
pub fn plan_offload(graph: &Graph, pp: &PrePartition, devices: &[DeviceState], topo: &Topology) -> OffloadPlan {
    assert!(!devices.is_empty());
    let nseg = pp.segments.len();
    let ndev = devices.len();

    // Per-(segment, device) latency & energy: distribute the model's
    // per-layer costs proportionally to segment MACs + bytes. We profile
    // the full model per device once, then scale by segment share.
    let cost = crate::graph::CostProfile::of(graph);
    let total_macs: f64 = cost.total_macs() as f64;
    let mut seg_lat = vec![vec![0.0f64; ndev]; nseg];
    let mut seg_en = vec![vec![0.0f64; ndev]; nseg];
    for (di, d) in devices.iter().enumerate() {
        let lat = estimate_latency(&cost, &d.snap);
        let en = estimate_energy(&cost, &d.snap);
        for (si, seg) in pp.segments.iter().enumerate() {
            let share = if total_macs > 0.0 { seg.macs as f64 / total_macs } else { 0.0 };
            seg_lat[si][di] = lat.total_s * share;
            seg_en[si][di] = en.total_j * share;
        }
    }
    let seg_mem: Vec<f64> = pp
        .segments
        .iter()
        .map(|s| s.param_bytes as f64 + s.out_bytes as f64 * 2.0)
        .collect();

    // DP over boundaries: state = (boundary i, device d) meaning segments
    // [0..i) are done and the frontier tensor lives on d.
    const INF: f64 = f64::INFINITY;
    let mut dist = vec![vec![INF; ndev]; nseg + 1];
    let mut prev: Vec<Vec<Option<(usize, usize)>>> = vec![vec![None; ndev]; nseg + 1];
    dist[0][0] = 0.0; // input data starts on the local device
    // Track per-device memory cumulatively per path is NP-hard in general;
    // we enforce it greedily: a move to device d is allowed only if the
    // segment fits the remaining budget consumed by contiguous runs.
    // Since runs are contiguous and devices may repeat, we approximate by
    // requiring each single segment to fit its host's budget and check the
    // final plan exactly (rejecting if violated).
    for i in 0..nseg {
        for d in 0..ndev {
            if dist[i][d] == INF {
                continue;
            }
            let frontier_bytes = if i == 0 {
                graph.node(graph.input).shape.bytes()
            } else {
                pp.segments[i - 1].out_bytes
            };
            for nd in 0..ndev {
                if seg_mem[i] > devices[nd].mem_budget {
                    continue;
                }
                let hop = if d == nd {
                    0.0
                } else {
                    match topo.delay_s(&devices[d].snap.device, &devices[nd].snap.device, frontier_bytes) {
                        Some(t) => t,
                        None => continue,
                    }
                };
                let cand = dist[i][d] + hop + seg_lat[i][nd];
                if cand < dist[i + 1][nd] {
                    dist[i + 1][nd] = cand;
                    prev[i + 1][nd] = Some((d, i));
                }
            }
        }
    }

    // Output must come home: add the return hop of the final logits.
    let out_bytes = graph.outputs.iter().map(|&o| graph.node(o).shape.bytes()).sum::<usize>();
    let mut best_d = 0;
    let mut best = INF;
    for d in 0..ndev {
        if dist[nseg][d] == INF {
            continue;
        }
        let home = if d == 0 {
            0.0
        } else {
            topo.delay_s(&devices[d].snap.device, &devices[0].snap.device, out_bytes).unwrap_or(INF)
        };
        if dist[nseg][d] + home < best {
            best = dist[nseg][d] + home;
            best_d = d;
        }
    }

    // No device chain reached the end (disconnected topology, or memory
    // budgets — possibly the local device's own — exclude some segment on
    // every path): degrade to the predicted local-only plan rather than
    // panic in reconstruction. Feasibility against the local budget is the
    // caller's call (Eq. 3 / best-effort), not the planner's.
    if nseg == 0 || !best.is_finite() || prev[nseg][best_d].is_none() {
        let lat: f64 = seg_lat.iter().map(|r| r[0]).sum();
        let en: f64 = seg_en.iter().map(|r| r[0]).sum();
        let mem: f64 = seg_mem.iter().sum();
        return OffloadPlan::local_only(&devices[0].snap.device, nseg, lat, en, mem);
    }

    // Reconstruct the assignment.
    let mut assign = vec![0usize; nseg];
    let mut cur = best_d;
    let mut i = nseg;
    while i > 0 {
        assign[i - 1] = cur;
        let (pd, pi) = prev[i][cur].expect("path broken");
        cur = pd;
        i = pi;
    }

    // Collapse into contiguous placements + tally costs.
    let mut placements: Vec<Placement> = Vec::new();
    let mut energy = 0.0;
    let mut transfer = 0usize;
    for (si, &d) in assign.iter().enumerate() {
        energy += seg_en[si][d];
        if let Some(last) = placements.last_mut() {
            if last.device == devices[d].snap.device {
                last.segments.push(si);
                continue;
            }
        }
        placements.push(Placement { device: devices[d].snap.device.clone(), segments: vec![si] });
    }
    for w in assign.windows(2) {
        if w[0] != w[1] {
            transfer += pp.segments[w[0]].out_bytes; // wait: out of seg i = index of first in pair
        }
    }
    // Fix transfer accounting: bytes leaving segment si cross iff assign
    // changes between si and si+1.
    transfer = 0;
    for si in 0..nseg.saturating_sub(1) {
        if assign[si] != assign[si + 1] {
            transfer += pp.segments[si].out_bytes;
        }
    }
    energy += crate::profiler::transmission_energy_j(transfer);

    let local_mem: f64 = assign
        .iter()
        .enumerate()
        .filter(|(_, &d)| d == 0)
        .map(|(si, _)| seg_mem[si])
        .sum();

    OffloadPlan { placements, latency_s: best, energy_j: energy, local_memory_bytes: local_mem, transfer_bytes: transfer }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{device, ContextState, ResourceMonitor};
    use crate::models::{resnet18, ResNetStyle};
    use crate::partition::prepartition::prepartition;

    fn state(name: &str, mem_gb: f64) -> DeviceState {
        let snap = ResourceMonitor::new(device(name).unwrap()).idle_snapshot();
        DeviceState { snap, mem_budget: mem_gb * 1e9 }
    }

    #[test]
    fn offload_to_faster_peer_helps() {
        let g = resnet18(ResNetStyle::Cifar, 100, 1);
        let pp = prepartition(&g);
        let topo = Topology::wifi_pair("raspberrypi-4b", "jetson-nx");
        let devs = vec![state("raspberrypi-4b", 4.0), state("jetson-nx", 8.0)];
        let plan = plan_offload(&g, &pp, &devs, &topo);
        let local = plan_offload(&g, &pp, &devs[..1], &topo);
        assert!(plan.latency_s <= local.latency_s);
        // A 13× faster peer over fast WiFi should actually win.
        assert!(!plan.is_local_only(), "expected offloading, got local-only");
    }

    #[test]
    fn slow_link_keeps_local() {
        let g = resnet18(ResNetStyle::Cifar, 100, 1);
        let pp = prepartition(&g);
        let mut topo = Topology::new();
        topo.connect("raspberrypi-4b", "jetson-nx", 0.1, 500.0); // 100 kbit/s
        let devs = vec![state("raspberrypi-4b", 4.0), state("jetson-nx", 8.0)];
        let plan = plan_offload(&g, &pp, &devs, &topo);
        assert!(plan.is_local_only(), "100kbit link must not offload: {:?}", plan.placements);
    }

    #[test]
    fn local_memory_drops_when_offloading() {
        let g = resnet18(ResNetStyle::Cifar, 100, 1);
        let pp = prepartition(&g);
        let topo = Topology::wifi_pair("raspberrypi-4b", "jetson-nx");
        let devs = vec![state("raspberrypi-4b", 4.0), state("jetson-nx", 8.0)];
        let plan = plan_offload(&g, &pp, &devs, &topo);
        let local = plan_offload(&g, &pp, &devs[..1], &topo);
        if !plan.is_local_only() {
            assert!(plan.local_memory_bytes < local.local_memory_bytes);
        }
    }

    #[test]
    fn three_device_plan_valid() {
        let g = resnet18(ResNetStyle::Cifar, 100, 1);
        let pp = prepartition(&g);
        let mut topo = Topology::new();
        topo.connect("raspberrypi-4b", "jetson-nx", 80.0, 4.0);
        topo.connect("raspberrypi-4b", "jetson-nano", 80.0, 4.0);
        topo.connect("jetson-nx", "jetson-nano", 80.0, 4.0);
        let devs = vec![state("raspberrypi-4b", 4.0), state("jetson-nx", 8.0), state("jetson-nano", 4.0)];
        let plan = plan_offload(&g, &pp, &devs, &topo);
        let covered: usize = plan.placements.iter().map(|p| p.segments.len()).sum();
        assert_eq!(covered, pp.segments.len());
        assert!(plan.latency_s.is_finite());
    }

    // ── degradation edge cases: every one must yield a valid local-only
    //    plan, never a panic ───────────────────────────────────────────

    /// A peer with no link to the local device can never receive a
    /// segment: the plan is local-only.
    #[test]
    fn missing_link_degrades_to_local_only() {
        let g = resnet18(ResNetStyle::Cifar, 100, 1);
        let pp = prepartition(&g);
        let topo = Topology::new(); // no links at all
        let devs = vec![state("raspberrypi-4b", 4.0), state("jetson-nx", 8.0)];
        let plan = plan_offload(&g, &pp, &devs, &topo);
        assert!(plan.is_local_only(), "disconnected peer must not receive work");
        assert_eq!(plan.transfer_bytes, 0);
        assert!(plan.latency_s.is_finite());
        let covered: usize = plan.placements.iter().map(|p| p.segments.len()).sum();
        assert_eq!(covered, pp.segments.len());
    }

    /// A nominally connected link with (near-)zero bandwidth makes every
    /// transfer astronomically expensive: the planner stays local instead
    /// of dividing by zero or offloading into a stall.
    #[test]
    fn zero_bandwidth_link_stays_local() {
        let g = resnet18(ResNetStyle::Cifar, 100, 1);
        let pp = prepartition(&g);
        for mbps in [0.0, 1e-9] {
            let mut topo = Topology::new();
            topo.connect("raspberrypi-4b", "jetson-nx", mbps, 4.0);
            let devs = vec![state("raspberrypi-4b", 4.0), state("jetson-nx", 8.0)];
            let plan = plan_offload(&g, &pp, &devs, &topo);
            assert!(plan.is_local_only(), "{mbps} Mbit/s link must not offload");
            assert!(plan.latency_s.is_finite());
        }
    }

    /// A peer whose memory budget excludes every segment contributes
    /// nothing: the plan is local-only even over a fast link.
    #[test]
    fn peer_memory_exclusion_degrades_to_local_only() {
        let g = resnet18(ResNetStyle::Cifar, 100, 1);
        let pp = prepartition(&g);
        let topo = Topology::wifi_pair("raspberrypi-4b", "jetson-nx");
        let mut peer = state("jetson-nx", 8.0);
        peer.mem_budget = 0.0;
        let devs = vec![state("raspberrypi-4b", 4.0), peer];
        let plan = plan_offload(&g, &pp, &devs, &topo);
        assert!(plan.is_local_only(), "memory-excluded peer must not receive segments");
        assert_eq!(plan.placements[0].device, "raspberrypi-4b");
    }

    /// Even when NO device (local included) fits some segment, the
    /// planner falls back to the predicted local-only plan — the Eq. 3
    /// feasibility check downstream decides what to do with it.
    #[test]
    fn nothing_fits_anywhere_falls_back_to_local_only() {
        let g = resnet18(ResNetStyle::Cifar, 100, 1);
        let pp = prepartition(&g);
        let topo = Topology::wifi_pair("raspberrypi-4b", "jetson-nx");
        let devs = vec![state("raspberrypi-4b", 0.0), state("jetson-nx", 0.0)];
        let plan = plan_offload(&g, &pp, &devs, &topo);
        assert!(plan.is_local_only());
        assert!(plan.latency_s.is_finite(), "fallback carries the predicted local latency");
        assert!(plan.local_memory_bytes > 0.0);
    }

    // ── plan → route weights (shard router priors) ─────────────────────

    #[test]
    fn route_weights_cover_participating_devices_only() {
        let g = resnet18(ResNetStyle::Cifar, 100, 1);
        let pp = prepartition(&g);
        let topo = Topology::wifi_pair("raspberrypi-4b", "jetson-nx");
        let devs = vec![state("raspberrypi-4b", 4.0), state("jetson-nx", 8.0)];
        let plan = plan_offload(&g, &pp, &devs, &topo);
        assert!(!plan.is_local_only(), "fast peer should participate");
        assert!(plan.involves("jetson-nx"));
        let w = plan.route_weight("jetson-nx").expect("participating peer has a weight");
        assert!((w - plan.latency_s).abs() < 1e-12);
        assert_eq!(plan.route_weight("jetson-nano"), None, "absent devices have no weight");
    }

    /// Segment runs round-trip through the plan: runs are contiguous,
    /// cover every segment in order, and the plan's `transfer_bytes`
    /// total is exactly the sum of the pre-partition's per-boundary
    /// frontier bytes at the run boundaries — so the serving layer can
    /// price each cut individually and still agree with the planner.
    #[test]
    fn segment_runs_match_prepartition_frontier_bytes() {
        let g = resnet18(ResNetStyle::Cifar, 100, 1);
        let pp = prepartition(&g);
        let topo = Topology::wifi_pair("raspberrypi-4b", "jetson-nx");
        let devs = vec![state("raspberrypi-4b", 4.0), state("jetson-nx", 8.0)];
        let plan = plan_offload(&g, &pp, &devs, &topo);
        let runs = plan.segment_runs();
        assert_eq!(runs.len(), plan.placements.len());
        let mut next = 0usize;
        let mut cut_transfer = 0usize;
        for (i, (_, r)) in runs.iter().enumerate() {
            assert_eq!(r.start, next, "runs must be contiguous and in order");
            next = r.end;
            if i + 1 < runs.len() {
                cut_transfer += pp.frontier_bytes(r.end).expect("interior boundary");
            }
        }
        assert_eq!(next, pp.n_segments(), "runs must cover every segment");
        assert_eq!(
            cut_transfer, plan.transfer_bytes,
            "per-boundary frontier bytes must sum to the plan's transfer total"
        );
    }

    /// The round trip holds on degraded plans too: local-only (explicit
    /// and via PR 3's disconnected-topology hardening) has one full run,
    /// zero transfer, and no split cut.
    #[test]
    fn local_only_and_degraded_plans_round_trip_with_no_cut() {
        let g = resnet18(ResNetStyle::Cifar, 100, 1);
        let pp = prepartition(&g);
        let explicit = OffloadPlan::local_only("raspberrypi-4b", pp.n_segments(), 0.01, 0.1, 1.0);
        let degraded = {
            let topo = Topology::new(); // no links: hardening path
            let devs = vec![state("raspberrypi-4b", 4.0), state("jetson-nx", 8.0)];
            plan_offload(&g, &pp, &devs, &topo)
        };
        for plan in [&explicit, &degraded] {
            assert!(plan.is_local_only());
            let runs = plan.segment_runs();
            assert_eq!(runs.len(), 1);
            assert_eq!(runs[0].1, 0..pp.n_segments());
            assert_eq!(plan.transfer_bytes, 0);
            assert_eq!(plan.split_cut(), None, "local-only plans have no cut to stream at");
        }
    }

    /// `split_cut` recognises exactly the single-cut local→peer shape.
    #[test]
    fn split_cut_covers_single_cut_plans_only() {
        let seg = |d: &str, segs: Vec<usize>| Placement { device: d.into(), segments: segs };
        let split = OffloadPlan {
            placements: vec![seg("local", vec![0, 1]), seg("edge", vec![2, 3])],
            latency_s: 0.004,
            energy_j: 0.1,
            local_memory_bytes: 1.0,
            transfer_bytes: 256,
        };
        assert_eq!(split.split_cut(), Some(("local", "edge", 2)));
        assert_eq!(split.segment_runs(), vec![("local", 0..2), ("edge", 2..4)]);

        let full_remote =
            OffloadPlan { placements: vec![seg("edge", vec![0, 1, 2, 3])], ..split.clone() };
        assert_eq!(full_remote.split_cut(), None, "cut 0 is full-remote routing, not a split");

        let bouncing = OffloadPlan {
            placements: vec![seg("local", vec![0]), seg("edge", vec![1, 2]), seg("local", vec![3])],
            ..split.clone()
        };
        assert_eq!(bouncing.split_cut(), None, "multi-run chains cannot stream one frontier");

        // A remote-first chain is still reported — the *router* decides
        // whether the head is its local device or another peer.
        let remote_first = OffloadPlan {
            placements: vec![seg("edge", vec![0, 1]), seg("local", vec![2, 3])],
            ..split
        };
        assert_eq!(remote_first.split_cut(), Some(("edge", "local", 2)));
    }

    #[test]
    fn contention_on_local_pushes_work_out() {
        let g = resnet18(ResNetStyle::Cifar, 100, 1);
        let pp = prepartition(&g);
        let topo = Topology::wifi_pair("raspberrypi-4b", "jetson-nano");
        let mon = ResourceMonitor::new(device("raspberrypi-4b").unwrap());
        let mut ctx = ContextState::idle();
        ctx.freq_frac = 0.4;
        ctx.cache_share = 0.2;
        let busy_local = DeviceState { snap: mon.sample(&ctx), mem_budget: 4e9 };
        let devs = vec![busy_local, state("jetson-nano", 4.0)];
        let plan = plan_offload(&g, &pp, &devs, &topo);
        assert!(!plan.is_local_only());
    }
}
