//! Inter-device network link model for offloading (Sec. III-B).
//!
//! Substitution note: the paper offloads over real WiFi between
//! phones/boards (device IP + PORT). We model links as
//! bandwidth+RTT pairs with optional time-varying traces, which is exactly
//! the quantity the paper's transmission-delay term consumes
//! (feature bytes / bandwidth).

use std::collections::HashMap;

use crate::sync::{read_or_recover, write_or_recover, Arc, RwLock};

/// A directed link between two devices.
#[derive(Debug, Clone)]
pub struct Link {
    pub from: String,
    pub to: String,
    /// Bandwidth in bytes/second.
    pub bytes_per_s: f64,
    /// Round-trip latency in seconds.
    pub rtt_s: f64,
}

impl Link {
    /// Time to move `bytes` across this link.
    pub fn delay_s(&self, bytes: usize) -> f64 {
        self.rtt_s / 2.0 + bytes as f64 / self.bytes_per_s.max(1.0)
    }
}

/// A mutable, shareable view of one live link's quality. The serving
/// layer's simulated remote peers read it per request while tests and
/// context traces mutate it mid-run — the time-varying bandwidth of the
/// paper's campus case study, applied to a single peer link instead of a
/// whole [`Topology`]. Cloning shares the underlying link.
#[derive(Debug, Clone)]
pub struct SharedLink(Arc<RwLock<Link>>);

impl SharedLink {
    /// A fresh link with the given bandwidth (Mbit/s) and RTT (ms).
    pub fn new(mbps: f64, rtt_ms: f64) -> SharedLink {
        SharedLink::of(Link {
            from: "local".into(),
            to: "peer".into(),
            bytes_per_s: mbps * 1e6 / 8.0,
            rtt_s: rtt_ms / 1e3,
        })
    }

    /// Wrap an existing link description.
    pub fn of(link: Link) -> SharedLink {
        SharedLink(Arc::new(RwLock::new(link)))
    }

    /// Current time to move `bytes` across the link.
    pub fn delay_s(&self, bytes: usize) -> f64 {
        read_or_recover(&self.0).delay_s(bytes)
    }

    /// Replace the link quality outright.
    pub fn set(&self, mbps: f64, rtt_ms: f64) {
        let mut l = write_or_recover(&self.0);
        l.bytes_per_s = mbps * 1e6 / 8.0;
        l.rtt_s = rtt_ms / 1e3;
    }

    /// Scale the current bandwidth (a degradation/recovery trace step).
    pub fn scale_bandwidth(&self, factor: f64) {
        write_or_recover(&self.0).bytes_per_s *= factor;
    }

    pub fn bytes_per_s(&self) -> f64 {
        read_or_recover(&self.0).bytes_per_s
    }

    pub fn rtt_s(&self) -> f64 {
        read_or_recover(&self.0).rtt_s
    }
}

/// The cluster topology: devices + pairwise links. Missing links mean the
/// pair cannot offload to each other.
#[derive(Debug, Clone, Default)]
pub struct Topology {
    links: HashMap<(String, String), Link>,
}

impl Topology {
    pub fn new() -> Self {
        Topology::default()
    }

    /// Add a symmetric link between two devices.
    pub fn connect(&mut self, a: &str, b: &str, mbps: f64, rtt_ms: f64) {
        let bps = mbps * 1e6 / 8.0;
        self.links.insert(
            (a.to_string(), b.to_string()),
            Link { from: a.into(), to: b.into(), bytes_per_s: bps, rtt_s: rtt_ms / 1e3 },
        );
        self.links.insert(
            (b.to_string(), a.to_string()),
            Link { from: b.into(), to: a.into(), bytes_per_s: bps, rtt_s: rtt_ms / 1e3 },
        );
    }

    pub fn link(&self, from: &str, to: &str) -> Option<&Link> {
        self.links.get(&(from.to_string(), to.to_string()))
    }

    /// Transfer delay, or None if disconnected. Zero-cost for same device.
    pub fn delay_s(&self, from: &str, to: &str, bytes: usize) -> Option<f64> {
        if from == to {
            return Some(0.0);
        }
        self.link(from, to).map(|l| l.delay_s(bytes))
    }

    /// Scale all bandwidths by a factor (models the time-varying traces of
    /// the campus case study).
    pub fn scale_bandwidth(&mut self, factor: f64) {
        for l in self.links.values_mut() {
            l.bytes_per_s *= factor;
        }
    }

    /// A standard two-device WiFi testbed (the paper's common scenario:
    /// local device + one edge peer over ~80 Mbit/s WiFi, 4 ms RTT).
    pub fn wifi_pair(a: &str, b: &str) -> Topology {
        let mut t = Topology::new();
        t.connect(a, b, 80.0, 4.0);
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delay_includes_rtt_and_bytes() {
        let t = Topology::wifi_pair("a", "b");
        let d = t.delay_s("a", "b", 10_000_000).unwrap();
        // 10 MB over 10 MB/s plus 2 ms half-RTT.
        assert!((d - (1.0 + 0.002)).abs() < 1e-6, "d={d}");
    }

    #[test]
    fn same_device_free() {
        let t = Topology::wifi_pair("a", "b");
        assert_eq!(t.delay_s("a", "a", 123456), Some(0.0));
    }

    #[test]
    fn disconnected_is_none() {
        let t = Topology::wifi_pair("a", "b");
        assert_eq!(t.delay_s("a", "c", 1), None);
    }

    #[test]
    fn symmetric() {
        let t = Topology::wifi_pair("a", "b");
        assert_eq!(t.delay_s("a", "b", 1000), t.delay_s("b", "a", 1000));
    }

    #[test]
    fn bandwidth_scaling() {
        let mut t = Topology::wifi_pair("a", "b");
        let before = t.delay_s("a", "b", 1_000_000).unwrap();
        t.scale_bandwidth(0.5);
        let after = t.delay_s("a", "b", 1_000_000).unwrap();
        assert!(after > before * 1.5);
    }

    // ── live shared links ──────────────────────────────────────────────

    #[test]
    fn shared_link_mutations_are_visible_through_clones() {
        let link = SharedLink::new(80.0, 4.0);
        let view = link.clone();
        let healthy = view.delay_s(1_000_000);
        // 1 MB over 10 MB/s plus 2 ms half-RTT.
        assert!((healthy - 0.102).abs() < 1e-6, "healthy={healthy}");
        link.scale_bandwidth(0.1);
        let degraded = view.delay_s(1_000_000);
        assert!((degraded - 1.002).abs() < 1e-6, "degraded={degraded}");
        link.set(80.0, 4.0);
        assert!((view.delay_s(1_000_000) - healthy).abs() < 1e-9, "recovery restores the trace");
    }

    #[test]
    fn shared_link_zero_bandwidth_is_finite() {
        let link = SharedLink::new(0.0, 4.0);
        // Link::delay_s floors bandwidth at 1 byte/s: enormous but finite,
        // so planners and routers degrade instead of dividing by zero.
        let d = link.delay_s(1000);
        assert!(d.is_finite());
        assert!(d > 100.0);
    }
}
