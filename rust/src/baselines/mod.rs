//! Baseline systems the paper compares against (Sec. IV-A):
//!
//! * handcrafted compression — Fire, SVD, MobileNetV2 (fixed designs);
//! * on-demand compression — AdaDeep (meta-learned combination, offline,
//!   no engine/offload), Once-for-all (supernet subnet selection);
//! * adaptive partition — CAS / DADS live in [`crate::partition`].
//!
//! All baselines run *without* the model-adaptive engine and *without*
//! runtime cross-level adaptation — that is precisely the paper's claimed
//! gap, so keeping them single-level is the faithful reproduction.

pub mod adadeep;
pub mod ofa;

pub use adadeep::adadeep_select;
pub use ofa::ofa_select;

use crate::compress::VariantSpec;
use crate::device::ResourceSnapshot;
use crate::engine::EngineConfig;
use crate::graph::Graph;
use crate::optimizer::{evaluate, Candidate, Evaluated};

/// Evaluate a handcrafted baseline by name on a model/device.
/// "fire" and "svd" transform the given graph; "mobilenet_v2" is a fixed
/// architecture and is evaluated as-is by the caller.
pub fn handcrafted(base: &Graph, name: &str, base_acc: f64, snap: &ResourceSnapshot) -> Option<Evaluated> {
    let spec = match name {
        "fire" => VariantSpec::single(crate::compress::OperatorKind::Fire, 0.5),
        "svd" => VariantSpec::single(crate::compress::OperatorKind::LowRank, 0.5),
        _ => return None,
    };
    let cand = Candidate { spec, offload: false, engine: EngineConfig::none() };
    Some(evaluate(base, &cand, base_acc, snap, 0.0, false))
}

/// Capacity ratio of a variant (shared by baseline selectors).
pub(crate) fn capacity_ratio(base: &Graph, spec: &VariantSpec) -> f64 {
    let v = spec.apply(base);
    v.total_macs() as f64 / base.total_macs().max(1) as f64
}

/// The unmodified original model with no engine help (paper's "Original
/// model" rows).
pub fn original(base: &Graph, base_acc: f64, snap: &ResourceSnapshot) -> Evaluated {
    evaluate(base, &Candidate::baseline(), base_acc, snap, 0.0, false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{device, ResourceMonitor};
    use crate::models::{resnet18, ResNetStyle};

    #[test]
    fn handcrafted_baselines_compress() {
        let g = resnet18(ResNetStyle::Cifar, 100, 1);
        let snap = ResourceMonitor::new(device("raspberrypi-4b").unwrap()).idle_snapshot();
        let orig = original(&g, 76.23, &snap);
        for name in ["fire", "svd"] {
            let e = handcrafted(&g, name, 76.23, &snap).unwrap();
            assert!(e.metrics.params < orig.metrics.params, "{name}");
        }
        assert!(handcrafted(&g, "nope", 76.23, &snap).is_none());
    }
}
