//! Once-for-all baseline (Cai et al., ICLR'20): train one supernet, then
//! select a subnetwork per deployment target without retraining.
//!
//! Reproduced at the granularity the paper uses it: the subnet space is a
//! width × depth grid over the backbone (OFA's elastic width/depth/kernel
//! axes — kernel elasticity folds into our composite operator), and
//! selection picks the highest-predicted-accuracy subnet satisfying the
//! latency constraint on the target device. Like AdaDeep, OFA is
//! algorithm-level only: no engine co-optimization, no runtime loop.

use crate::compress::{OperatorKind, VariantSpec};
use crate::device::ResourceSnapshot;
use crate::engine::EngineConfig;
use crate::graph::Graph;
use crate::optimizer::{evaluate, Candidate, Evaluated};

/// The OFA subnet grid: (width multiplier, depth multiplier) pairs.
pub fn subnet_grid() -> Vec<VariantSpec> {
    let mut v = vec![VariantSpec::identity()];
    for w in [1.0, 0.75, 0.5, 0.35] {
        for d in [1.0, 0.75, 0.5] {
            if w == 1.0 && d == 1.0 {
                continue;
            }
            let mut ops = Vec::new();
            if w < 1.0 {
                ops.push((OperatorKind::ChannelScale, w));
            }
            if d < 1.0 {
                ops.push((OperatorKind::DepthScale, d));
            }
            v.push(VariantSpec { ops });
        }
    }
    v
}

/// Select the best OFA subnet under a latency budget on the target device.
pub fn ofa_select(base: &Graph, base_acc: f64, snap: &ResourceSnapshot, lat_budget_s: f64) -> Evaluated {
    let mut best: Option<Evaluated> = None;
    for spec in subnet_grid() {
        let cand = Candidate { spec, offload: false, engine: EngineConfig::none() };
        let e = evaluate(base, &cand, base_acc, snap, 0.0, false);
        let feasible = e.metrics.latency_s <= lat_budget_s;
        let better = match &best {
            None => true,
            Some(b) => {
                let b_feasible = b.metrics.latency_s <= lat_budget_s;
                match (feasible, b_feasible) {
                    (true, false) => true,
                    (false, true) => false,
                    (true, true) => e.metrics.accuracy > b.metrics.accuracy,
                    (false, false) => e.metrics.latency_s < b.metrics.latency_s,
                }
            }
        };
        if better {
            best = Some(e);
        }
    }
    best.unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{device, ResourceMonitor};
    use crate::models::{resnet18, ResNetStyle};

    fn setup() -> (Graph, ResourceSnapshot) {
        let g = resnet18(ResNetStyle::Cifar, 100, 1);
        let snap = ResourceMonitor::new(device("raspberrypi-4b").unwrap()).idle_snapshot();
        (g, snap)
    }

    #[test]
    fn grid_has_expected_size() {
        // identity + 11 (4×3 − identity) = 12
        assert_eq!(subnet_grid().len(), 12);
    }

    #[test]
    fn loose_budget_picks_full_model() {
        let (g, snap) = setup();
        let e = ofa_select(&g, 76.23, &snap, f64::INFINITY);
        assert!(e.candidate.spec.ops.is_empty(), "picked {:?}", e.candidate.spec);
    }

    #[test]
    fn tight_budget_picks_subnet() {
        let (g, snap) = setup();
        let full = ofa_select(&g, 76.23, &snap, f64::INFINITY);
        let tight = ofa_select(&g, 76.23, &snap, full.metrics.latency_s * 0.4);
        assert!(!tight.candidate.spec.ops.is_empty());
        assert!(tight.metrics.latency_s < full.metrics.latency_s);
        assert!(tight.metrics.accuracy <= full.metrics.accuracy);
    }

    #[test]
    fn infeasible_budget_returns_fastest() {
        let (g, snap) = setup();
        let e = ofa_select(&g, 76.23, &snap, 1e-9);
        // Must return the minimum-latency subnet rather than panic.
        let all: Vec<f64> = subnet_grid()
            .into_iter()
            .map(|s| {
                let c = Candidate { spec: s, offload: false, engine: EngineConfig::none() };
                evaluate(&g, &c, 76.23, &snap, 0.0, false).metrics.latency_s
            })
            .collect();
        let min = all.iter().cloned().fold(f64::MAX, f64::min);
        assert!((e.metrics.latency_s - min).abs() < 1e-9);
    }
}
