//! AdaDeep baseline (Liu et al., TMC'20): usage-driven, automated
//! combination of compression techniques via a learned meta-controller.
//!
//! Reproduced as the paper positions it: an *offline, algorithm-level*
//! selector. The meta-controller is modelled as a greedy sequential
//! composer (the published system's DQN converges to greedy-like
//! compositions on these operator menus): starting from the original
//! model, repeatedly apply the single (operator, level) step that
//! maximizes a usage-driven reward until no step improves it. Crucially —
//! AdaDeep gets **no back-end engine, no offloading, and no runtime
//! re-adaptation**; its choice is frozen at deploy time. That is the gap
//! Fig. 8/9/10 measure.

use crate::compress::{OperatorKind, VariantSpec};
use crate::device::ResourceSnapshot;
use crate::engine::EngineConfig;
use crate::graph::Graph;
use crate::optimizer::{evaluate, Candidate, Evaluated};

/// AdaDeep's usage-driven reward (its paper's weighted sum of accuracy,
/// energy, latency, and size terms, normalized to the original model).
fn reward(e: &Evaluated, orig: &Evaluated, lat_budget_s: f64) -> f64 {
    let acc = e.metrics.accuracy / 100.0;
    let energy = e.metrics.energy_j / orig.metrics.energy_j.max(1e-12);
    let size = e.metrics.params / orig.metrics.params.max(1.0);
    let lat_pen = if e.metrics.latency_s > lat_budget_s { 1.0 } else { 0.0 };
    2.0 * acc - 0.5 * energy - 0.3 * size - 1.0 * lat_pen
}

/// Run the AdaDeep selector: returns the chosen configuration evaluated on
/// the deployment snapshot (engine off — AdaDeep is algorithm-level only).
pub fn adadeep_select(base: &Graph, base_acc: f64, snap: &ResourceSnapshot, lat_budget_s: f64) -> Evaluated {
    let orig = evaluate(base, &Candidate::baseline(), base_acc, snap, 0.0, false);
    let mut current_spec = VariantSpec::identity();
    let mut current = orig.clone();
    let menu: Vec<(OperatorKind, f64)> = OperatorKind::all()
        .into_iter()
        .flat_map(|k| [(k, 0.75), (k, 0.5), (k, 0.25)])
        .collect();

    for _step in 0..3 {
        let mut best: Option<(f64, VariantSpec, Evaluated)> = None;
        for &(k, level) in &menu {
            if current_spec.ops.iter().any(|&(ok, _)| ok == k) {
                continue; // one application per family, like AdaDeep's layers
            }
            let mut spec = current_spec.clone();
            spec.ops.push((k, level));
            let cand = Candidate { spec: spec.clone(), offload: false, engine: EngineConfig::none() };
            let e = evaluate(base, &cand, base_acc, snap, 0.0, false);
            let r = reward(&e, &orig, lat_budget_s);
            if best.as_ref().map(|(br, _, _)| r > *br).unwrap_or(true) {
                best = Some((r, spec, e));
            }
        }
        let (r, spec, e) = best.unwrap();
        if r <= reward(&current, &orig, lat_budget_s) {
            break; // no improving step — stop, like the DQN's terminal action
        }
        current_spec = spec;
        current = e;
    }
    current
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{device, ResourceMonitor};
    use crate::models::{resnet18, ResNetStyle};
    use crate::optimizer::{search, SearchConfig};

    fn setup() -> (Graph, ResourceSnapshot) {
        let g = resnet18(ResNetStyle::Cifar, 100, 1);
        let snap = ResourceMonitor::new(device("raspberrypi-4b").unwrap()).idle_snapshot();
        (g, snap)
    }

    #[test]
    fn adadeep_compresses_vs_original() {
        let (g, snap) = setup();
        let orig = evaluate(&g, &Candidate::baseline(), 76.23, &snap, 0.0, false);
        let ada = adadeep_select(&g, 76.23, &snap, 1.0);
        assert!(ada.metrics.params < orig.metrics.params);
        assert!(ada.metrics.latency_s < orig.metrics.latency_s);
        assert!(!ada.candidate.spec.ops.is_empty());
    }

    #[test]
    fn adadeep_has_no_engine() {
        let (g, snap) = setup();
        let ada = adadeep_select(&g, 76.23, &snap, 1.0);
        assert_eq!(ada.candidate.engine, EngineConfig::none());
        assert!(!ada.candidate.offload);
    }

    #[test]
    fn crowdhmtware_front_dominates_or_matches_adadeep() {
        // The headline claim (Fig. 8): cross-level beats algorithm-only.
        let (g, snap) = setup();
        let ada = adadeep_select(&g, 76.23, &snap, 1.0);
        let front = search(&g, 76.23, &snap, &SearchConfig { population: 24, generations: 4, seed: 9 });
        // Some front point must beat AdaDeep on latency AND memory without
        // losing accuracy.
        let wins = front.iter().any(|e| {
            e.metrics.latency_s < ada.metrics.latency_s
                && e.metrics.memory_bytes < ada.metrics.memory_bytes
                && e.metrics.accuracy >= ada.metrics.accuracy - 0.1
        });
        assert!(wins, "no front point dominates AdaDeep");
    }
}
