//! Front-end elastic inference (Sec. III-A): the retraining-free
//! multi-variant compression space. Operators η1–η6 transform the graph
//! IR; [`VariantSpec`] names a point in the space; [`variant_space`]
//! enumerates the candidate grid the optimizer searches.

pub mod operators;
pub mod rewrite;

pub use operators::{apply, OperatorKind};


use crate::graph::Graph;

/// A point in the compression space: an ordered list of (operator, level)
/// applications. θp in the paper's Eq. 3.
#[derive(Debug, Clone, PartialEq)]
pub struct VariantSpec {
    pub ops: Vec<(OperatorKind, f64)>,
}

impl VariantSpec {
    pub fn identity() -> Self {
        VariantSpec { ops: vec![] }
    }

    pub fn single(op: OperatorKind, level: f64) -> Self {
        VariantSpec { ops: vec![(op, level)] }
    }

    pub fn pair(a: (OperatorKind, f64), b: (OperatorKind, f64)) -> Self {
        VariantSpec { ops: vec![a, b] }
    }

    /// Apply all operators in order.
    pub fn apply(&self, g: &Graph) -> Graph {
        let mut out = g.clone();
        for &(op, level) in &self.ops {
            out = apply(&out, op, level);
        }
        out
    }

    /// Operator kinds used (for the accuracy model's per-family deltas).
    pub fn kinds(&self) -> Vec<OperatorKind> {
        self.ops.iter().map(|&(k, _)| k).collect()
    }

    /// Human-readable label like "η1+η6".
    pub fn label(&self) -> String {
        if self.ops.is_empty() {
            return "original".into();
        }
        self.ops.iter().map(|(k, _)| k.symbol()).collect::<Vec<_>>().join("+")
    }

    /// Label with levels, e.g. "η1(0.25)+η6(0.35)" — distinguishes
    /// same-family variants in adaptation traces.
    pub fn detailed_label(&self) -> String {
        if self.ops.is_empty() {
            return "original".into();
        }
        self.ops
            .iter()
            .map(|(k, l)| format!("{}({l})", k.symbol()))
            .collect::<Vec<_>>()
            .join("+")
    }
}

/// The candidate grid the optimizer searches: identity, each operator at
/// three levels, and the paper's featured pair combinations (Table III,
/// Fig. 13 use η1+η5, η1+η6, η2+η5, η2+η6).
pub fn variant_space() -> Vec<VariantSpec> {
    let mut v = vec![VariantSpec::identity()];
    for k in OperatorKind::all() {
        for level in [0.75, 0.5, 0.25] {
            v.push(VariantSpec::single(k, level));
        }
    }
    for (a, b) in [
        (OperatorKind::LowRank, OperatorKind::DepthScale),
        (OperatorKind::LowRank, OperatorKind::ChannelScale),
        (OperatorKind::Fire, OperatorKind::DepthScale),
        (OperatorKind::Fire, OperatorKind::ChannelScale),
        (OperatorKind::Ghost, OperatorKind::ChannelScale),
        (OperatorKind::Composite, OperatorKind::DepthScale),
    ] {
        for (la, lb) in [(0.5, 0.5), (0.25, 0.5), (0.5, 0.75)] {
            v.push(VariantSpec::pair((a, la), (b, lb)));
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{resnet18, ResNetStyle};

    #[test]
    fn space_has_identity_and_pairs() {
        let space = variant_space();
        assert!(space.len() > 30);
        assert_eq!(space[0], VariantSpec::identity());
        assert!(space.iter().any(|v| v.label() == "η1+η6"));
    }

    #[test]
    fn every_variant_applies_cleanly_to_resnet18() {
        let g = resnet18(ResNetStyle::Cifar, 100, 1);
        for spec in variant_space() {
            let c = spec.apply(&g);
            assert!(c.total_macs() > 0, "{}", spec.label());
            assert_eq!(c.node(c.outputs[0]).shape.features(), 100, "{}", spec.label());
        }
    }

    #[test]
    fn pair_compresses_more_than_either_single() {
        let g = resnet18(ResNetStyle::Cifar, 100, 1);
        let a = VariantSpec::single(OperatorKind::LowRank, 0.5).apply(&g);
        let pair = VariantSpec::pair((OperatorKind::LowRank, 0.5), (OperatorKind::ChannelScale, 0.5)).apply(&g);
        assert!(pair.total_macs() < a.total_macs());
    }

    #[test]
    fn labels() {
        assert_eq!(VariantSpec::identity().label(), "original");
        assert_eq!(
            VariantSpec::pair((OperatorKind::Fire, 0.5), (OperatorKind::ChannelScale, 0.5)).label(),
            "η2+η6"
        );
    }
}
