//! The six compression-operator families η1–η6 (Sec. III-A1), each a
//! retraining-free graph→graph transformation. Weight consistency across
//! variants is handled by the ensemble pre-training of the backbone
//! (python side); here we transform structure and account costs.

use std::collections::HashSet;


use crate::graph::{Conv2dAttrs, Graph, Op};

use super::rewrite::{residual_blocks, rewrite, Emit};

/// The operator families. Levels in (0,1]: smaller = more aggressive.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OperatorKind {
    /// η1: low-rank (SVD-style) convolution factorization.
    LowRank,
    /// η2: Fire squeeze-expand channel merging.
    Fire,
    /// η3: composite (EfficientNet-style) kernel/channel/resolution scaling.
    Composite,
    /// η4: Ghost modules — half real convs, half cheap linear expansions.
    Ghost,
    /// η5: depth-wise scaling — bypass residual blocks / early exits.
    DepthScale,
    /// η6: channel-wise scaling — width multiplier pruning.
    ChannelScale,
}

impl OperatorKind {
    pub fn all() -> [OperatorKind; 6] {
        [
            OperatorKind::LowRank,
            OperatorKind::Fire,
            OperatorKind::Composite,
            OperatorKind::Ghost,
            OperatorKind::DepthScale,
            OperatorKind::ChannelScale,
        ]
    }

    pub fn symbol(self) -> &'static str {
        match self {
            OperatorKind::LowRank => "η1",
            OperatorKind::Fire => "η2",
            OperatorKind::Composite => "η3",
            OperatorKind::Ghost => "η4",
            OperatorKind::DepthScale => "η5",
            OperatorKind::ChannelScale => "η6",
        }
    }
}

/// Apply one operator at `level` ∈ (0,1] to a graph.
pub fn apply(g: &Graph, op: OperatorKind, level: f64) -> Graph {
    let level = level.clamp(0.05, 1.0);
    match op {
        OperatorKind::LowRank => low_rank(g, level),
        OperatorKind::Fire => fire(g),
        OperatorKind::Composite => composite(g, level),
        OperatorKind::Ghost => ghost(g),
        OperatorKind::DepthScale => depth_scale(g, level),
        OperatorKind::ChannelScale => channel_scale(g, level),
    }
}

/// η1 — replace every dense k×k conv (k>1) with a (k×1, rank r) → (1×k,
/// out_c) factorized pair, r = level·min(in_c, out_c).
pub fn low_rank(g: &Graph, level: f64) -> Graph {
    let mut out = rewrite(g, |g, n, new, map| {
        if let Op::Conv2d(a) = &n.op {
            if a.groups == 1 && a.kernel.0 > 1 && a.kernel.1 > 1 {
                let in_c = g.node(n.inputs[0]).shape.channels();
                let rank = (((in_c.min(a.out_c)) as f64) * level).ceil().max(1.0) as usize;
                let first = Conv2dAttrs {
                    out_c: rank,
                    kernel: (a.kernel.0, 1),
                    stride: (a.stride.0, 1),
                    pad: (a.pad.0, 0),
                    groups: 1,
                    bias: false,
                };
                let second = Conv2dAttrs {
                    out_c: a.out_c,
                    kernel: (1, a.kernel.1),
                    stride: (1, a.stride.1),
                    pad: (0, a.pad.1),
                    groups: 1,
                    bias: a.bias,
                };
                let inputs: Vec<_> = n.inputs.iter().map(|i| map[i]).collect();
                let c1 = new.add(format!("{}.lr_a", n.name), Op::Conv2d(first), &inputs);
                let c2 = new.add(format!("{}.lr_b", n.name), Op::Conv2d(second), &[c1]);
                return Emit::Mapped(c2);
            }
        }
        Emit::Keep
    });
    out.name = format!("{}+η1", g.name);
    out
}

/// η2 — replace every dense 3×3 stride-1 conv with a Fire module:
/// squeeze 1×1 (c/4) → expand 1×1 (c/2) ∥ expand 3×3 (c/2) → concat.
pub fn fire(g: &Graph) -> Graph {
    let mut out = rewrite(g, |_, n, new, map| {
        if let Op::Conv2d(a) = &n.op {
            if a.groups == 1 && a.kernel == (3, 3) && a.stride == (1, 1) && a.out_c >= 8 {
                let s = (a.out_c / 4).max(1);
                let e = a.out_c / 2;
                let inputs: Vec<_> = n.inputs.iter().map(|i| map[i]).collect();
                let sq = new.add(format!("{}.squeeze", n.name), Op::Conv2d(Conv2dAttrs::pointwise(s)), &inputs);
                let e1 = new.add(format!("{}.expand1", n.name), Op::Conv2d(Conv2dAttrs::pointwise(e)), &[sq]);
                let e3 = new.add(format!("{}.expand3", n.name), Op::Conv2d(Conv2dAttrs::simple(e, 3, 1, 1)), &[sq]);
                let cat = new.add(format!("{}.concat", n.name), Op::Concat, &[e1, e3]);
                return Emit::Mapped(cat);
            }
        }
        Emit::Keep
    });
    out.name = format!("{}+η2", g.name);
    out
}

/// η3 — composite scaling: channel width × level, plus kernel-size
/// reduction (5×5/7×7 → 3×3) when level < 0.7.
pub fn composite(g: &Graph, level: f64) -> Graph {
    let mut out = channel_scale_inner(g, level);
    if level < 0.7 {
        out = rewrite(&out, |_, n, _, _| {
            let _ = n;
            Emit::Keep
        });
        for n in &mut out.nodes {
            if let Op::Conv2d(a) = &mut n.op {
                if a.kernel.0 > 3 && a.kernel.1 > 3 {
                    a.kernel = (3, 3);
                    a.pad = (1, 1);
                }
            }
        }
        out.recompute_shapes();
    }
    out.name = format!("{}+η3", g.name);
    out
}

/// η4 — Ghost modules: each dense 3×3 conv produces only half its output
/// channels with real convs; the other half comes from a cheap depthwise
/// 3×3 on the primary maps, concatenated.
pub fn ghost(g: &Graph) -> Graph {
    let mut out = rewrite(g, |_, n, new, map| {
        if let Op::Conv2d(a) = &n.op {
            if a.groups == 1 && a.kernel == (3, 3) && a.out_c >= 8 && a.out_c % 2 == 0 {
                let half = a.out_c / 2;
                let mut primary = a.clone();
                primary.out_c = half;
                let inputs: Vec<_> = n.inputs.iter().map(|i| map[i]).collect();
                let p = new.add(format!("{}.ghost_primary", n.name), Op::Conv2d(primary), &inputs);
                let cheap = Conv2dAttrs::depthwise(half, 3, 1, 1);
                let c = new.add(format!("{}.ghost_cheap", n.name), Op::Conv2d(cheap), &[p]);
                let cat = new.add(format!("{}.ghost_cat", n.name), Op::Concat, &[p, c]);
                return Emit::Mapped(cat);
            }
        }
        Emit::Keep
    });
    out.name = format!("{}+η4", g.name);
    out
}

/// η5 — depth scaling: bypass `1 − level` of the identity-shortcut
/// residual blocks (evenly spaced, keeping the first), deriving a
/// shallower variant via skip connections.
pub fn depth_scale(g: &Graph, level: f64) -> Graph {
    let blocks = residual_blocks(g);
    let n_remove = ((blocks.len() as f64) * (1.0 - level)).round() as usize;
    let n_remove = n_remove.min(blocks.len());
    // Evenly-spaced selection from the back (later blocks are most
    // redundant per the depth-elastic pruning literature).
    let mut remove: HashSet<usize> = HashSet::new();
    let mut skip_nodes: HashSet<usize> = HashSet::new();
    let mut chosen = 0usize;
    for (add, _s, chain) in blocks.iter().rev() {
        if chosen >= n_remove {
            break;
        }
        remove.insert(*add);
        for c in chain {
            skip_nodes.insert(*c);
        }
        chosen += 1;
    }
    let mut out = rewrite(g, |g, n, _new, map| {
        if remove.contains(&n.id) {
            // Alias the Add to its shortcut input.
            let (_, short, _) = residual_blocks(g).into_iter().find(|(a, _, _)| *a == n.id).unwrap();
            return Emit::Alias(map[&short]);
        }
        if skip_nodes.contains(&n.id) {
            // Dead branch — alias to its input; prune_dead removes it.
            return Emit::Alias(map[&n.inputs[0]]);
        }
        Emit::Keep
    });
    out.prune_dead();
    out.name = format!("{}+η5", g.name);
    out
}

/// η6 — channel scaling: multiply every conv's output channels (and FC
/// hidden widths) by `level`, keeping classifier outputs intact.
pub fn channel_scale(g: &Graph, level: f64) -> Graph {
    let mut out = channel_scale_inner(g, level);
    out.name = format!("{}+η6", g.name);
    out
}

fn channel_scale_inner(g: &Graph, level: f64) -> Graph {
    let consumers = g.consumers();

    // Width-coupling analysis: Add requires both inputs to share channel
    // width, so their width *sources* (the convs/FCs that defined the
    // width) must scale together — and if any source is unscalable (the
    // graph input, a Concat), the whole group must keep its width.
    // Union-find over width sources, computed in storage (topo) order.
    let n = g.len();
    let mut parent: Vec<usize> = (0..n).collect();
    fn find(parent: &mut Vec<usize>, mut x: usize) -> usize {
        while parent[x] != x {
            parent[x] = parent[parent[x]];
            x = parent[x];
        }
        x
    }
    let mut unscalable = vec![false; n];
    let mut is_concat = vec![false; n];
    let mut add_coupled: Vec<usize> = Vec::new();
    // src[i] = node id that determines node i's channel width.
    let mut src = vec![0usize; n];
    for node in &g.nodes {
        let id = node.id;
        src[id] = match &node.op {
            Op::Input => {
                unscalable[id] = true;
                id
            }
            Op::Conv2d(a) | Op::FusedConvBn { conv: a, .. } | Op::FusedPointwise { conv: a, .. } => {
                if a.groups == 1 {
                    id // scalable width source
                } else {
                    src[node.inputs[0]] // depthwise passes width through
                }
            }
            Op::FC { .. } | Op::FusedFcAct { .. } => id,
            Op::Flatten => {
                unscalable[id] = true;
                id
            }
            Op::Concat => {
                // A concat's width is the *sum* of its members': members
                // scale together (union them), but the summed width can
                // never match another rounded width inside an Add — so a
                // concat group that also contains an Add must freeze.
                is_concat[id] = true;
                for &i in &node.inputs {
                    let a = find(&mut parent, src[i]);
                    let b = find(&mut parent, id);
                    parent[a] = b;
                }
                id
            }
            Op::Add => {
                let a = find(&mut parent, src[node.inputs[0]]);
                let b = find(&mut parent, src[node.inputs[1]]);
                parent[a] = b;
                add_coupled.push(src[node.inputs[0]]);
                src[node.inputs[0]]
            }
            _ => src[node.inputs[0]],
        };
    }
    // Per-root flags → frozen roots: any unscalable member, or a concat
    // participating in an Add-coupled group.
    let mut has_unscalable = std::collections::HashSet::new();
    let mut has_concat = std::collections::HashSet::new();
    let mut has_add = std::collections::HashSet::new();
    for i in 0..n {
        let r = find(&mut parent, i);
        if unscalable[i] {
            has_unscalable.insert(r);
        }
        if is_concat[i] {
            has_concat.insert(r);
        }
    }
    for &a in &add_coupled {
        let r = find(&mut parent, a);
        has_add.insert(r);
    }
    let mut frozen_root = std::collections::HashSet::new();
    for i in 0..n {
        let r = find(&mut parent, i);
        if has_unscalable.contains(&r) || (has_concat.contains(&r) && has_add.contains(&r)) {
            frozen_root.insert(r);
        }
    }
    let scalable = |parent: &mut Vec<usize>, id: usize| -> bool {
        let r = find(parent, id);
        !frozen_root.contains(&r)
    };

    let mut out = g.clone();
    for node in &mut out.nodes {
        let id = node.id;
        match &mut node.op {
            Op::Conv2d(a) => {
                if a.groups == 1 && scalable(&mut parent, id) {
                    a.out_c = ((a.out_c as f64 * level).round() as usize).max(1);
                }
                // Depthwise convs follow their input width (fixed below).
            }
            Op::FC { out: o, .. } => {
                // Hidden FC layers scale; the final classifier (feeding
                // softmax or a graph output) keeps its width.
                let is_classifier = consumers[id]
                    .iter()
                    .all(|&c| g.node(c).op.kind() == "Softmax")
                    || g.outputs.contains(&id);
                if !is_classifier && scalable(&mut parent, id) {
                    *o = ((*o as f64 * level).round() as usize).max(1);
                }
            }
            _ => {}
        }
    }
    // Fix depthwise convs in topo order: groups/out_c must track the (now
    // narrower) input.
    fix_depthwise(&mut out);
    out.recompute_shapes();
    // Residual adds stay consistent: coupled sources scaled identically
    // (same rounding) or not at all (frozen groups).
    out
}

fn fix_depthwise(g: &mut Graph) {
    // Single forward pass in storage (topological) order: fix each
    // depthwise conv's groups/out_c to its (already updated) input width,
    // recomputing shapes inline so downstream fixups see fresh widths.
    for i in 0..g.nodes.len() {
        let input_shapes: Vec<crate::graph::Shape> =
            g.nodes[i].inputs.iter().map(|&j| g.nodes[j].shape.clone()).collect();
        if let Op::Conv2d(a) = &mut g.nodes[i].op {
            if a.groups > 1 {
                let in_c = input_shapes[0].channels();
                a.groups = in_c;
                a.out_c = in_c;
            }
        }
        if !matches!(g.nodes[i].op, Op::Input) {
            let refs: Vec<&crate::graph::Shape> = input_shapes.iter().collect();
            g.nodes[i].shape = g.nodes[i].op.infer_shape(&refs);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{mobilenet_v2, resnet18, vgg16, ResNetStyle};

    fn r18() -> Graph {
        resnet18(ResNetStyle::Cifar, 100, 1)
    }

    #[test]
    fn low_rank_cuts_params_preserves_shapes() {
        let g = r18();
        let c = low_rank(&g, 0.25);
        assert!(c.total_params() < g.total_params() / 2);
        assert_eq!(c.node(c.outputs[0]).shape, g.node(g.outputs[0]).shape);
    }

    #[test]
    fn low_rank_level_monotone() {
        let g = r18();
        let a = low_rank(&g, 0.5);
        let b = low_rank(&g, 0.25);
        assert!(b.total_params() < a.total_params());
        assert!(a.total_params() < g.total_params());
    }

    #[test]
    fn fire_cuts_params_preserves_output() {
        let g = vgg16(false, 100, 1);
        let c = fire(&g);
        assert!(c.total_params() < g.total_params());
        assert_eq!(c.node(c.outputs[0]).shape, g.node(g.outputs[0]).shape);
    }

    #[test]
    fn ghost_roughly_halves_conv_cost() {
        let g = vgg16(false, 100, 1);
        let c = ghost(&g);
        let ratio = c.total_macs() as f64 / g.total_macs() as f64;
        assert!((0.3..0.85).contains(&ratio), "ratio={ratio}");
        assert_eq!(c.node(c.outputs[0]).shape, g.node(g.outputs[0]).shape);
    }

    #[test]
    fn depth_scale_removes_blocks() {
        let g = r18();
        let c = depth_scale(&g, 0.4);
        assert!(c.len() < g.len());
        assert!(c.total_macs() < g.total_macs());
        assert_eq!(c.node(c.outputs[0]).shape, g.node(g.outputs[0]).shape);
    }

    #[test]
    fn depth_scale_level_one_is_identity_cost() {
        let g = r18();
        let c = depth_scale(&g, 1.0);
        assert_eq!(c.total_macs(), g.total_macs());
    }

    #[test]
    fn channel_scale_quadratic_param_reduction() {
        let g = vgg16(false, 100, 1);
        let c = channel_scale(&g, 0.5);
        let ratio = c.total_params() as f64 / g.total_params() as f64;
        // Conv params scale ~level² (both in and out channels shrink).
        assert!((0.15..0.45).contains(&ratio), "ratio={ratio}");
        assert_eq!(c.node(c.outputs[0]).shape.features(), 100);
    }

    #[test]
    fn channel_scale_handles_depthwise_mobilenet() {
        let g = mobilenet_v2(false, 10, 1);
        let c = channel_scale(&g, 0.5);
        assert!(c.total_macs() < g.total_macs());
        assert_eq!(c.node(c.outputs[0]).shape.features(), 10);
        assert_eq!(c.topo_order().len(), c.len());
    }

    #[test]
    fn composite_scales_channels() {
        let g = r18();
        let c = composite(&g, 0.6);
        assert!(c.total_macs() < g.total_macs());
    }

    #[test]
    fn apply_dispatches_all_kinds() {
        let g = r18();
        for k in OperatorKind::all() {
            let c = apply(&g, k, 0.5);
            assert!(c.total_macs() <= g.total_macs(), "{k:?} should not grow the model");
            assert_eq!(
                c.node(c.outputs[0]).shape.features(),
                100,
                "{k:?} must keep the classifier width"
            );
        }
    }

    #[test]
    fn residual_add_shapes_stay_consistent_after_scaling() {
        let g = r18();
        let c = channel_scale(&g, 0.3);
        // recompute_shapes would have panicked on mismatched Adds; verify
        // explicitly for good measure.
        for n in &c.nodes {
            if n.op.kind() == "Add" {
                assert_eq!(c.node(n.inputs[0]).shape, c.node(n.inputs[1]).shape);
            }
        }
    }
}
