//! Graph-rewrite machinery shared by the compression operators: walk the
//! source graph in stored (topological) order, let a callback emit zero or
//! more replacement nodes into a fresh graph, and remap edges/outputs.

use std::collections::HashMap;

use crate::graph::{Graph, Node, NodeId};

/// Outcome of rewriting one node.
pub enum Emit {
    /// Keep the node as-is (op cloned, inputs remapped).
    Keep,
    /// The node was replaced by `new_id` already emitted into the new
    /// graph (use for multi-node expansions — emit them yourself via the
    /// builder, then return the final id).
    Mapped(NodeId),
    /// Skip this node entirely, aliasing its output to an already-mapped
    /// node (used by depth-scaling to bypass residual blocks).
    Alias(NodeId),
}

/// Rewrite `g` node-by-node. `f` receives the old graph, the old node, the
/// new graph under construction, and the old→new id map; it returns how to
/// emit the node. Graph outputs are remapped automatically.
pub fn rewrite<F>(g: &Graph, mut f: F) -> Graph
where
    F: FnMut(&Graph, &Node, &mut Graph, &HashMap<NodeId, NodeId>) -> Emit,
{
    let mut out = Graph::new(g.name.clone(), g.nodes[g.input].shape.clone());
    let mut map: HashMap<NodeId, NodeId> = HashMap::new();
    map.insert(g.input, out.input);
    for n in &g.nodes {
        if n.id == g.input {
            continue;
        }
        let new_id = match f(g, n, &mut out, &map) {
            Emit::Keep => {
                let inputs: Vec<NodeId> = n.inputs.iter().map(|i| map[i]).collect();
                out.add(n.name.clone(), n.op.clone(), &inputs)
            }
            Emit::Mapped(id) | Emit::Alias(id) => id,
        };
        map.insert(n.id, new_id);
    }
    for o in &g.outputs {
        let id = map[o];
        out.mark_output(id);
    }
    out
}

/// Collect, for each Add node with an identity shortcut, the set of node
/// ids forming the bypassable main branch (shortcut input excluded).
/// Returns `(add_id, shortcut_id, branch_nodes)` triples.
pub fn residual_blocks(g: &Graph) -> Vec<(NodeId, NodeId, Vec<NodeId>)> {
    let mut found = Vec::new();
    for n in &g.nodes {
        if n.op.kind() != "Add" || n.inputs.len() != 2 {
            continue;
        }
        for (mi, si) in [(0usize, 1usize), (1, 0)] {
            let main = n.inputs[mi];
            let short = n.inputs[si];
            // Walk the single-input chain backwards from `main`; if it hits
            // `short`, the branch is bypassable (identity shortcut).
            let mut chain = Vec::new();
            let mut cur = main;
            let mut ok = false;
            for _ in 0..64 {
                if cur == short {
                    ok = true;
                    break;
                }
                let node = g.node(cur);
                if node.inputs.len() != 1 {
                    break;
                }
                chain.push(cur);
                cur = node.inputs[0];
            }
            if ok && !chain.is_empty() && g.node(short).shape == n.shape {
                found.push((n.id, short, chain));
                break;
            }
        }
    }
    found
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Activation, Conv2dAttrs, Op, Shape};
    use crate::models::{resnet18, ResNetStyle};

    #[test]
    fn identity_rewrite_preserves_costs() {
        let g = resnet18(ResNetStyle::Cifar, 100, 1);
        let g2 = rewrite(&g, |_, _, _, _| Emit::Keep);
        assert_eq!(g2.total_macs(), g.total_macs());
        assert_eq!(g2.total_params(), g.total_params());
        assert_eq!(g2.outputs.len(), g.outputs.len());
    }

    #[test]
    fn finds_identity_residual_blocks_in_resnet() {
        let g = resnet18(ResNetStyle::Cifar, 100, 1);
        let blocks = residual_blocks(&g);
        // ResNet-18 CIFAR: 8 basic blocks, 5 with identity shortcuts
        // (stage-leading blocks use projection shortcuts).
        assert_eq!(blocks.len(), 5, "got {}", blocks.len());
        for (add, short, chain) in &blocks {
            assert_eq!(g.node(*add).op.kind(), "Add");
            assert!(!chain.is_empty());
            assert_eq!(g.node(*short).shape, g.node(*add).shape);
        }
    }

    #[test]
    fn multi_node_expansion_via_mapped() {
        let mut g = Graph::new("t", Shape::nchw(1, 3, 8, 8));
        let c = g.add("c", Op::Conv2d(Conv2dAttrs::simple(4, 3, 1, 1)), &[g.input]);
        g.mark_output(c);
        // Replace the conv with conv→relu.
        let g2 = rewrite(&g, |_, n, out, map| {
            if n.op.kind() == "Conv2d" {
                let inputs: Vec<_> = n.inputs.iter().map(|i| map[i]).collect();
                let c = out.add("c2", n.op.clone(), &inputs);
                let r = out.add("r", Op::Act(Activation::ReLU), &[c]);
                Emit::Mapped(r)
            } else {
                Emit::Keep
            }
        });
        assert_eq!(g2.len(), 3);
        assert_eq!(g2.node(g2.outputs[0]).op.kind(), "Act");
    }
}
