//! Regenerates Fig. 11 (offloading vs CAS/DADS).
fn main() {
    let rows = crowdhmtware::experiments::fig11::run();
    crowdhmtware::experiments::fig11::table(&rows).print();
}
