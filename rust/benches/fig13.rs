//! Regenerates Fig. 13 (campus case study trace).
fn main() {
    let log = crowdhmtware::experiments::fig13::run(6);
    crowdhmtware::experiments::fig13::table(&log).print();
}
