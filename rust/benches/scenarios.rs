//! §Perf open-loop scenario suite: trace-driven load + scripted fleet
//! dynamics against the live router + pool stack (see
//! `crowdhmtware::workload` for the measurement model and the mapping
//! onto the paper's Sec. IV evaluation).
//!
//! Six named scenarios, all replayable by seed:
//!
//!   steady_poisson   — Poisson arrivals well inside capacity; the
//!                      Tab. 4 steady-state baseline, AIMD sizer live
//!   diurnal          — one sinusoidal day/night period (campus load
//!                      shape), sizer live
//!   flash_crowd_x8   — a ×8 burst pushes offered load past capacity
//!                      for 400 ms; open-loop measurement keeps the
//!                      backlog in the tail, admission control rejects
//!                      past the bounded queues
//!   churn_under_load — peers join, a link collapses, the busiest peer
//!                      *dies* mid-run; asserts the dead-lane drain
//!                      answers every admitted caller (failed == 0)
//!   campus_replay    — Sec. IV-G: a drone joins, battery sag slows
//!                      the local device, the decision level switches
//!                      to an energy variant
//!   tenant_flash_crowd — a governed aggressor tenant bursts ×8 while
//!                      a victim tenant stays inside its contract; the
//!                      tenancy arm clips the aggressor at the front
//!                      door, the victim's p99 is gated on its own
//!                      (`tenant_flash_crowd_victim`)
//!
//! Latency is charged from each request's *scheduled arrival instant*
//! (no coordinated omission), so queueing under overload is visible in
//! p95/p99. The run emits `BENCH_scenarios.json` in the string-keyed
//! `scenarios` schema gated by `ci/check_bench.py` against
//! `ci/BENCH_scenarios_baseline.json` — p95 *and* p99 (the committed
//! baseline is intentionally slack; refresh it from a CI artifact, see
//! the check_bench docstring).
//!
//! Run: `cargo bench --bench scenarios`

use std::time::Duration;

use crowdhmtware::coordinator::{
    BatcherConfig, CacheConfig, ClassConfig, PoolConfig, ShardRouterConfig, TenancyConfig,
};
use crowdhmtware::device::{device, ResourceMonitor, ResourceSnapshot};
use crowdhmtware::optimizer::{PoolSizer, PoolSizerConfig};
use crowdhmtware::telemetry::TelemetrySnapshot;
use crowdhmtware::util::Json;
use crowdhmtware::workload::{
    run_scenario, ArrivalSchedule, Controller, FleetEvent, FleetScript, MaintainController,
    RequestMix, Scenario, ScenarioReport, ScenarioStack, StackConfig, Trace,
};

/// Base seed for every trace (scenario i uses SEED + i): same binary,
/// same arrivals, same request contents.
const SEED: u64 = 2026;

const CLASSES: usize = 4;
const ELEMS: usize = 64;

/// The stack every scenario runs on: a small local pool of sleep-based
/// [`crowdhmtware::workload::SimExec`] workers behind the shard router.
/// `peer_capacity` is kept small so a collapsed link strands a bounded
/// number of in-flight probes (the drain at peer death stays short).
fn stack_config(
    workers: usize,
    max_batch: usize,
    local_delay: Duration,
    cache: bool,
) -> StackConfig {
    StackConfig {
        classes: CLASSES,
        elems: ELEMS,
        batch_sizes: vec![1, 4, 8],
        local_delay,
        variant: "v".to_string(),
        pool: PoolConfig {
            workers,
            queue_capacity: 64,
            batcher: BatcherConfig { max_batch, max_wait: Duration::from_micros(500) },
            cache: CacheConfig { enabled: cache, capacity: 512, ..CacheConfig::default() },
            ..PoolConfig::default()
        },
        router: ShardRouterConfig { peer_capacity: 8, ..ShardRouterConfig::default() },
    }
}

fn mix() -> RequestMix {
    RequestMix {
        priority_share: 0.10,
        hot_share: 0.15,
        sizes: vec![(16, 0.5), (48, 0.3), (ELEMS, 0.2)],
        ..RequestMix::default()
    }
}

/// The full control plane: AIMD pool sizing from live telemetry plus
/// shard-admission reconciliation, ticked mid-run like
/// `optimizer::AdaptLoop` would.
struct SizerController {
    sizer: PoolSizer,
    snap: ResourceSnapshot,
    budget_s: f64,
}

impl SizerController {
    fn new(budget_s: f64) -> SizerController {
        let monitor = ResourceMonitor::new(device("jetson-nx").expect("profile exists"));
        SizerController {
            sizer: PoolSizer::new(PoolSizerConfig::default()),
            snap: monitor.idle_snapshot(),
            budget_s,
        }
    }
}

impl Controller for SizerController {
    fn tick(&mut self, stack: &ScenarioStack, tel: &TelemetrySnapshot) {
        if let Some(target) = self.sizer.decide(tel, &self.snap, self.budget_s).target() {
            stack.resize_workers(target);
        }
        stack.router().maintain(tel);
    }
}

fn steady_poisson() -> ScenarioReport {
    let stack = ScenarioStack::spawn(stack_config(2, 8, Duration::from_millis(2), true));
    let trace = Trace::generate(
        &ArrivalSchedule::Poisson { rate_hz: 1200.0 },
        &mix(),
        Duration::from_millis(1200),
        ELEMS,
        SEED,
    );
    let scenario = Scenario::new("steady_poisson", trace);
    let report = run_scenario(&stack, &scenario, &mut SizerController::new(0.050));
    stack.shutdown();
    report
}

fn diurnal() -> ScenarioReport {
    let stack = ScenarioStack::spawn(stack_config(2, 8, Duration::from_millis(2), true));
    let trace = Trace::generate(
        &ArrivalSchedule::Diurnal {
            base_hz: 1000.0,
            amplitude: 0.8,
            period: Duration::from_millis(1500),
        },
        &mix(),
        Duration::from_millis(1500),
        ELEMS,
        SEED + 1,
    );
    let scenario = Scenario::new("diurnal", trace);
    let report = run_scenario(&stack, &scenario, &mut SizerController::new(0.050));
    stack.shutdown();
    report
}

fn flash_crowd() -> ScenarioReport {
    // max_batch 4 on 2 ms batches caps each worker near 2000 req/s, so
    // the ×8 burst (4800 req/s offered) oversubscribes the 2-worker
    // stack: the backlog lands in p99 and the bounded queues reject the
    // overflow instead of buffering it without limit. Cache off — hot
    // requests must not quietly absorb the burst.
    let stack = ScenarioStack::spawn(stack_config(2, 4, Duration::from_millis(2), false));
    let trace = Trace::generate(
        &ArrivalSchedule::FlashCrowd {
            base_hz: 600.0,
            burst_factor: 8.0,
            burst_start: Duration::from_millis(500),
            burst_len: Duration::from_millis(400),
        },
        &mix(),
        Duration::from_millis(1400),
        ELEMS,
        SEED + 2,
    );
    let scenario = Scenario::new("flash_crowd_x8", trace);
    let report = run_scenario(&stack, &scenario, &mut MaintainController);
    stack.shutdown();
    report
}

fn churn_under_load() -> ScenarioReport {
    let stack = ScenarioStack::spawn(stack_config(2, 8, Duration::from_millis(2), false));
    // Peer 0 is attached before load starts and is attractive (low
    // prior, fast link) — it will carry traffic, then its link
    // collapses (124 ms per round trip, past the 50 ms degrade budget),
    // then it dies outright with probes still queued on the link.
    stack.add_peer("edge-a", Duration::from_millis(1), 200.0, 1.0, 0.002);
    let script = FleetScript::new()
        .at(
            Duration::from_millis(250),
            FleetEvent::PeerJoin {
                name: "edge-b".to_string(),
                exec_delay: Duration::from_millis(1),
                link_mbps: 200.0,
                link_rtt_ms: 1.0,
                prior_s: 0.002,
            },
        )
        .at(Duration::from_millis(500), FleetEvent::LinkSet { peer: 0, mbps: 0.5, rtt_ms: 120.0 })
        .at(Duration::from_millis(1050), FleetEvent::PeerDeath { peer: 0 })
        .at(Duration::from_millis(1150), FleetEvent::LinkScale { peer: 1, factor: 0.25 })
        .at(Duration::from_millis(1300), FleetEvent::LinkScale { peer: 1, factor: 4.0 });
    let trace = Trace::generate(
        &ArrivalSchedule::Poisson { rate_hz: 900.0 },
        &mix(),
        Duration::from_millis(1500),
        ELEMS,
        SEED + 3,
    );
    let scenario = Scenario::new("churn_under_load", trace).with_script(script);
    let report = run_scenario(&stack, &scenario, &mut MaintainController);

    // The regression this scenario exists for: a peer dying mid-run
    // must not fail a single admitted caller (kill_peer's dead-lane
    // drain), and the dead slot must stay out of routing.
    assert_eq!(report.load.failed, 0, "peer death stranded in-flight callers");
    assert_eq!(report.adaptation.peers_killed, 1);
    assert_eq!(report.adaptation.peers_joined, 1, "only edge-b joins inside the window");
    assert!(
        report.adaptation.degraded >= 1,
        "the collapsed link must degrade before the peer dies (got {})",
        report.adaptation.degraded
    );
    assert!(stack.router().shard_stats().peers[0].dead);
    stack.shutdown();
    report
}

fn campus_replay() -> ScenarioReport {
    let stack = ScenarioStack::spawn(stack_config(2, 8, Duration::from_micros(2500), true));
    let script = FleetScript::new()
        .at(
            Duration::from_millis(400),
            FleetEvent::PeerJoin {
                name: "drone".to_string(),
                exec_delay: Duration::from_micros(1200),
                link_mbps: 80.0,
                link_rtt_ms: 2.0,
                prior_s: 0.003,
            },
        )
        .at(Duration::from_millis(1000), FleetEvent::DeviceDrift { factor: 1.6 })
        .at(
            Duration::from_millis(1150),
            FleetEvent::VariantSwitch { variant: "e3-energy".to_string() },
        );
    let trace = Trace::generate(
        &ArrivalSchedule::Diurnal {
            base_hz: 700.0,
            amplitude: 0.6,
            period: Duration::from_millis(1600),
        },
        &RequestMix {
            priority_share: 0.05,
            hot_share: 0.25,
            sizes: vec![(16, 0.4), (32, 0.4), (ELEMS, 0.2)],
            ..RequestMix::default()
        },
        Duration::from_millis(1600),
        ELEMS,
        SEED + 4,
    );
    let scenario = Scenario::new("campus_replay", trace).with_script(script);
    let report = run_scenario(&stack, &scenario, &mut SizerController::new(0.050));
    assert_eq!(report.adaptation.switches, 1, "the scripted strategy switch must land");
    assert_eq!(report.adaptation.peers_joined, 1);
    stack.shutdown();
    report
}

fn tenant_flash_crowd() -> ScenarioReport {
    // Two tenants share the flash-crowd stack: the victim offers a
    // steady 400 req/s inside its admission contract while the
    // aggressor's ×8 burst (2400 req/s peak) would oversubscribe the
    // 2-worker pool on its own. The tenancy arm's token bucket clips
    // the aggressor at its contracted rate at the front door — before
    // the queues — so the victim's tail holds (gated below as
    // `tenant_flash_crowd_victim`) and the aggressor absorbs the
    // rejections.
    let mut cfg = stack_config(2, 4, Duration::from_millis(2), false);
    cfg.pool.tenancy = TenancyConfig {
        classes: vec![
            ClassConfig {
                tenant: "victim".to_string(),
                rate_hz: 800.0,
                burst: 64,
                reserve_frac: 0.5,
                retry_frac: 0.0,
            },
            ClassConfig {
                tenant: "aggressor".to_string(),
                rate_hz: 500.0,
                burst: 32,
                reserve_frac: 0.0,
                retry_frac: 0.0,
            },
        ],
    };
    let stack = ScenarioStack::spawn(cfg);
    let victim = Trace::generate(
        &ArrivalSchedule::Poisson { rate_hz: 400.0 },
        &mix(),
        Duration::from_millis(1400),
        ELEMS,
        SEED + 5,
    )
    .tagged("victim");
    let aggressor = Trace::generate(
        &ArrivalSchedule::FlashCrowd {
            base_hz: 300.0,
            burst_factor: 8.0,
            burst_start: Duration::from_millis(500),
            burst_len: Duration::from_millis(400),
        },
        &mix(),
        Duration::from_millis(1400),
        ELEMS,
        SEED + 6,
    )
    .tagged("aggressor");
    let scenario = Scenario::new("tenant_flash_crowd", Trace::merged(vec![victim, aggressor]));
    let report = run_scenario(&stack, &scenario, &mut MaintainController);

    // The tenancy accounting contract, asserted from the windowed
    // telemetry delta: every submission bumped exactly one of
    // admitted / rejected / retry_spent, so the counters reconstruct
    // the offered load exactly.
    for tenant in ["victim", "aggressor"] {
        let d = &report.window.per_tenant[tenant];
        let l = &report.load.per_tenant[tenant];
        assert_eq!(
            d.admitted + d.rejected + d.retry_spent,
            l.offered + l.retries_submitted,
            "{tenant}: per-tenant conservation broke"
        );
        assert_eq!(d.retry_spent, 0, "{tenant}: no retry policy configured");
    }
    let v = &report.load.per_tenant["victim"];
    let a = &report.load.per_tenant["aggressor"];
    assert!(
        a.rejected * 5 >= a.offered,
        "aggressor must absorb the burst as rejections: {} of {}",
        a.rejected,
        a.offered
    );
    assert!(
        v.rejected * 50 <= v.offered,
        "victim traffic inside its contract must be admitted: {} of {} rejected",
        v.rejected,
        v.offered
    );
    println!(
        "  tenant_flash_crowd: victim {}/{} rejected p99 {:.2} ms | aggressor {}/{} rejected",
        v.rejected, v.offered, v.p99_ms, a.rejected, a.offered
    );
    stack.shutdown();
    report
}

fn scenario_json(r: &ScenarioReport) -> Json {
    let a = &r.adaptation;
    Json::obj(vec![
        ("name", Json::str(r.name.as_str())),
        ("requests", Json::num(r.load.offered as f64)),
        ("offered_rps", Json::num(r.load.offered_rps)),
        ("req_per_s", Json::num(r.load.goodput_rps)),
        ("p50_ms", Json::num(r.load.p50_ms)),
        ("p95_ms", Json::num(r.load.p95_ms)),
        ("p99_ms", Json::num(r.load.p99_ms)),
        ("max_submit_lag_ms", Json::num(r.load.max_submit_lag_ms)),
        ("rejected", Json::num(r.load.rejected as f64)),
        ("failed", Json::num(r.load.failed as f64)),
        (
            "adaptation",
            Json::obj(vec![
                ("resizes", Json::num(a.resizes as f64)),
                ("switches", Json::num(a.switches as f64)),
                ("peers_joined", Json::num(a.peers_joined as f64)),
                ("peers_killed", Json::num(a.peers_killed as f64)),
                ("degraded", Json::num(a.degraded as f64)),
                ("readmitted", Json::num(a.readmitted as f64)),
                ("steals", Json::num(a.steals as f64)),
                ("cache_hits", Json::num(a.cache_hits as f64)),
            ]),
        ),
    ])
}

fn main() {
    println!("== open-loop scenario suite (seed {SEED}) ==");
    let reports = vec![
        steady_poisson(),
        diurnal(),
        flash_crowd(),
        churn_under_load(),
        campus_replay(),
        tenant_flash_crowd(),
    ];

    println!(
        "{:<18} {:>6} {:>9} {:>9} {:>8} {:>8} {:>8} {:>5} {:>5}  adaptation",
        "scenario", "reqs", "offer/s", "good/s", "p50ms", "p95ms", "p99ms", "rej", "fail"
    );
    for r in &reports {
        assert_eq!(
            r.load.completed + r.load.rejected + r.load.failed,
            r.load.offered,
            "{}: count conservation broke",
            r.name
        );
        let a = &r.adaptation;
        println!(
            "{:<18} {:>6} {:>9.0} {:>9.0} {:>8.2} {:>8.2} {:>8.2} {:>5} {:>5}  \
             rsz {} sw {} j {} k {} deg {} re {} steal {} hit {}",
            r.name,
            r.load.offered,
            r.load.offered_rps,
            r.load.goodput_rps,
            r.load.p50_ms,
            r.load.p95_ms,
            r.load.p99_ms,
            r.load.rejected,
            r.load.failed,
            a.resizes,
            a.switches,
            a.peers_joined,
            a.peers_killed,
            a.degraded,
            a.readmitted,
            a.steals,
            a.cache_hits
        );
    }

    let total: usize = reports.iter().map(|r| r.load.offered).sum();
    let mut scenarios: Vec<Json> = reports.iter().map(scenario_json).collect();
    // The isolation claim, as its own gated entry: the *victim's*
    // latency percentiles under the aggressor's burst.
    if let Some(r) = reports.iter().find(|r| r.name == "tenant_flash_crowd") {
        let v = &r.load.per_tenant["victim"];
        scenarios.push(Json::obj(vec![
            ("name", Json::str("tenant_flash_crowd_victim")),
            ("requests", Json::num(v.offered as f64)),
            (
                "req_per_s",
                Json::num(if r.load.wall_s > 0.0 {
                    v.completed as f64 / r.load.wall_s
                } else {
                    0.0
                }),
            ),
            ("p50_ms", Json::num(v.p50_ms)),
            ("p95_ms", Json::num(v.p95_ms)),
            ("p99_ms", Json::num(v.p99_ms)),
            ("rejected", Json::num(v.rejected as f64)),
        ]));
    }
    let doc = Json::obj(vec![
        ("bench", Json::str("scenarios")),
        ("seed", Json::num(SEED as f64)),
        ("requests", Json::num(total as f64)),
        ("scenarios", Json::Arr(scenarios)),
    ]);
    let path = "BENCH_scenarios.json";
    match std::fs::write(path, doc.to_string() + "\n") {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
