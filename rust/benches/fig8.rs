//! Regenerates Fig. 8 (CrowdHMTware vs AdaDeep over three models).
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let rows = crowdhmtware::experiments::fig8::run("raspberrypi-4b");
    crowdhmtware::experiments::fig8::table(&rows).print();
    for r in &rows {
        println!(
            "  {}: latency gain {:.1}x, memory gain {:.1}x, Δacc {:+.2}pp  (paper: 4.2x/3x/10.3x lat, 3.1-4.2x mem)",
            r.model,
            r.latency_gain(),
            r.memory_gain(),
            r.our_acc - r.ada_acc
        );
    }
    println!("fig8 generated in {:.2}s", t0.elapsed().as_secs_f64());
}
