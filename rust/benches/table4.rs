//! Regenerates Table IV (cross-level engine ablation @ Snapdragon 855).
fn main() {
    let rows = crowdhmtware::experiments::table4::run();
    crowdhmtware::experiments::table4::table(&rows).print();
}
