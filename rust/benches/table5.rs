//! Regenerates Table V (component ablation).
fn main() {
    let rows = crowdhmtware::experiments::table5::run();
    crowdhmtware::experiments::table5::table(&rows).print();
}
