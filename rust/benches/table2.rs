//! Regenerates Table II (dynamic memory budgets).
fn main() {
    let rows = crowdhmtware::experiments::table2::run();
    crowdhmtware::experiments::table2::table(&rows).print();
}
