//! Regenerates Table III (operator combinations across tasks).
fn main() {
    let rows = crowdhmtware::experiments::table3::run();
    crowdhmtware::experiments::table3::table(&rows).print();
}
