//! Regenerates Fig. 10 (elastic inference vs compression baselines).
fn main() {
    let rows = crowdhmtware::experiments::fig10::run();
    crowdhmtware::experiments::fig10::table(&rows).print();
}
