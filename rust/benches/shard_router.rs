//! Cross-device sharding throughput: a fixed local pool serving alone vs
//! with one / two simulated partition peers attached over a fast link
//! (no criterion in this offline environment — plain wall-clock runs).
//!
//! Each request costs a fixed per-batch delay wherever it runs; peers add
//! the analytic link-transfer cost of the 4 KB input. The router should
//! overlap local batches with remote round trips, so attached peers raise
//! sustained req/s; the table also reports the measured remote share.
//!
//! A second scenario records the **segment-streaming** trajectory: a
//! two-segment chain whose heavy tail runs 10× faster on the peer, over
//! a link that affords the 256 B frontier but not the 4 KB input — the
//! router splits at the seeded cut and the split-vs-full-remote
//! trajectory is captured from day one.
//!
//! Emits `BENCH_sharding.json` (the `split` key is schema-additive — the
//! CI gate reads `configs` only, like PR 4's `skewed` key):
//!
//! ```json
//! {"bench":"shard_router","requests":256,"batch_delay_ms":2,
//!  "configs":[{"peers":0,"req_per_s":...,"remote_share":0.0,
//!              "p95_ms":...}, ...],
//!  "split":{"requests":128,"req_per_s":...,"split_share":...,
//!           "p95_ms":...}}
//! ```
//!
//! Run: `cargo bench --bench shard_router`

use std::time::{Duration, Instant};

use anyhow::Result;
use crowdhmtware::coordinator::{
    BatcherConfig, Executor, PoolConfig, ServingPool, ShardRouter, ShardRouterConfig,
};
use crowdhmtware::partition::SharedLink;
use crowdhmtware::runtime::SegmentedExec;
use crowdhmtware::util::{Json, Table};

const CLASSES: usize = 4;
const ELEMS: usize = 1024;
const REQUESTS: usize = 256;
const BATCH_DELAY: Duration = Duration::from_millis(2);

struct MockExec;

impl Executor for MockExec {
    fn batch_sizes(&self, _v: &str) -> Vec<usize> {
        vec![1]
    }

    fn num_classes(&self) -> usize {
        CLASSES
    }

    fn input_elems(&self) -> usize {
        ELEMS
    }

    fn run(&mut self, _v: &str, batch: usize, _input: &[f32]) -> Result<Vec<f32>> {
        std::thread::sleep(BATCH_DELAY);
        Ok(vec![1.0 / CLASSES as f32; batch * CLASSES])
    }
}

struct ConfigResult {
    peers: usize,
    req_per_s: f64,
    remote_share: f64,
    p95_ms: f64,
}

fn run_config(peers: usize) -> ConfigResult {
    let pool = ServingPool::spawn(
        |_| Box::new(MockExec) as Box<dyn Executor>,
        "v",
        PoolConfig {
            workers: 2,
            queue_capacity: REQUESTS,
            batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_micros(100) },
            ..PoolConfig::default()
        },
    );
    let router = ShardRouter::new(
        pool,
        ShardRouterConfig {
            peer_capacity: REQUESTS,
            local_prior_s: BATCH_DELAY.as_secs_f64(),
            ..ShardRouterConfig::default()
        },
    );
    for p in 0..peers {
        router.add_simulated_peer(
            &format!("peer-{p}"),
            || Box::new(MockExec) as Box<dyn Executor>,
            SharedLink::new(200.0, 1.0),
            BATCH_DELAY.as_secs_f64(),
        );
    }
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..REQUESTS)
        .map(|_| router.submit(vec![0.0; ELEMS]).expect("capacity sized to the run"))
        .collect();
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(60)).expect("response");
    }
    let wall = t0.elapsed().as_secs_f64();
    let shard = router.shard_stats();
    let remote = shard.routed_remote();
    let stats = router.shutdown();
    assert_eq!(stats.served(), REQUESTS);
    ConfigResult {
        peers,
        req_per_s: REQUESTS as f64 / wall,
        remote_share: remote as f64 / REQUESTS as f64,
        p95_ms: stats.percentile(0.95) * 1e3,
    }
}

// ── segment-streaming scenario ────────────────────────────────────────

const SPLIT_REQUESTS: usize = 128;

struct SplitResult {
    req_per_s: f64,
    split_share: f64,
    p95_ms: f64,
}

/// Two-segment chain: `head_ms` then `tail_ms`, with a 64-element
/// (256 B) frontier at the cut over the 4 KB input.
fn chain(head_ms: u64, tail_ms: u64) -> SegmentedExec {
    SegmentedExec::new(
        CLASSES,
        vec![ELEMS, 64, CLASSES],
        vec![Duration::from_millis(head_ms), Duration::from_millis(tail_ms)],
    )
}

/// Local tail is 10 ms; the peer runs it in 1 ms; the 8 Mbit/s link
/// affords the frontier (~0.75 ms) but not the input (~4.6 ms). The
/// router should stream most traffic through `split@1`.
fn run_split_scenario() -> SplitResult {
    let pool = ServingPool::spawn(
        |_| Box::new(chain(1, 10)) as Box<dyn Executor>,
        "v",
        PoolConfig {
            workers: 2,
            queue_capacity: SPLIT_REQUESTS,
            batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_micros(100) },
            ..PoolConfig::default()
        },
    );
    let router = ShardRouter::new(
        pool,
        ShardRouterConfig {
            peer_capacity: SPLIT_REQUESTS,
            local_prior_s: 0.011,
            ..ShardRouterConfig::default()
        },
    );
    router.add_simulated_peer(
        "edge",
        || Box::new(chain(5, 1)) as Box<dyn Executor>,
        SharedLink::new(8.0, 1.0),
        0.011,
    );
    router.seed_split(0, 1, 0.003);
    // The peer thread publishes its segment capability asynchronously;
    // wait so the whole run sees the split route.
    for _ in 0..500 {
        if router.admitted_splits() == 1 {
            break;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..SPLIT_REQUESTS)
        .map(|_| router.submit(vec![0.0; ELEMS]).expect("capacity sized to the run"))
        .collect();
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(60)).expect("response");
    }
    let wall = t0.elapsed().as_secs_f64();
    let split = router.shard_stats().split_routed();
    let stats = router.shutdown();
    assert_eq!(stats.served(), SPLIT_REQUESTS);
    SplitResult {
        req_per_s: SPLIT_REQUESTS as f64 / wall,
        split_share: split as f64 / SPLIT_REQUESTS as f64,
        p95_ms: stats.percentile(0.95) * 1e3,
    }
}

fn main() {
    let mut table = Table::new(
        "Serving throughput vs attached peers (mock executors, 2 ms/batch)",
        &["peers", "req/s", "remote share", "p95 ms"],
    );
    let mut results = Vec::new();
    for peers in [0usize, 1, 2] {
        let r = run_config(peers);
        table.row(&[
            r.peers.to_string(),
            format!("{:.0}", r.req_per_s),
            format!("{:.2}", r.remote_share),
            format!("{:.2}", r.p95_ms),
        ]);
        results.push(r);
    }
    table.print();

    let split = run_split_scenario();
    let mut split_table = Table::new(
        "Segment streaming (2-seg chain, 10 ms local tail vs 1 ms remote, 8 Mbit/s link)",
        &["req/s", "split share", "p95 ms"],
    );
    split_table.row(&[
        format!("{:.0}", split.req_per_s),
        format!("{:.2}", split.split_share),
        format!("{:.2}", split.p95_ms),
    ]);
    split_table.print();

    let configs: Vec<Json> = results
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("peers", Json::num(r.peers as f64)),
                ("req_per_s", Json::num(r.req_per_s)),
                ("remote_share", Json::num(r.remote_share)),
                ("p95_ms", Json::num(r.p95_ms)),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("bench", Json::str("shard_router")),
        ("requests", Json::num(REQUESTS as f64)),
        ("batch_delay_ms", Json::num(BATCH_DELAY.as_secs_f64() * 1e3)),
        ("configs", Json::Arr(configs)),
        // Schema-additive (like PR 4's `skewed` key in BENCH_serving):
        // the CI gate reads `configs` only, so recording the split
        // trajectory cannot affect existing gates.
        (
            "split",
            Json::obj(vec![
                ("requests", Json::num(SPLIT_REQUESTS as f64)),
                ("req_per_s", Json::num(split.req_per_s)),
                ("split_share", Json::num(split.split_share)),
                ("p95_ms", Json::num(split.p95_ms)),
            ]),
        ),
    ]);
    let path = "BENCH_sharding.json";
    match std::fs::write(path, doc.to_string() + "\n") {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
