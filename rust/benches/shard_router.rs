//! Cross-device sharding throughput: a fixed local pool serving alone vs
//! with one / two simulated partition peers attached over a fast link
//! (no criterion in this offline environment — plain wall-clock runs).
//!
//! Each request costs a fixed per-batch delay wherever it runs; peers add
//! the analytic link-transfer cost of the 4 KB input. The router should
//! overlap local batches with remote round trips, so attached peers raise
//! sustained req/s; the table also reports the measured remote share.
//!
//! A second scenario records the **segment-streaming** trajectory: a
//! two-segment chain whose heavy tail runs 10× faster on the peer, over
//! a link that affords the 256 B frontier but not the 4 KB input — the
//! router splits at the seeded cut and the split-vs-full-remote
//! trajectory is captured from day one.
//!
//! A third scenario measures **frontier coalescing** (ISSUE 6): the same
//! split shape over a high-RTT link, served through a *wall-clock* peer
//! transport (transfer time actually slept, not analytically returned —
//! the batching win must show up in measured req/s, which the analytic
//! `SimulatedPeer` cannot do). A burst of concurrent split requests runs
//! with the link's coalescing window off (every frontier pays the round
//! trip alone) vs on (the window stacks frontiers into one transfer);
//! batching-on must win on throughput.
//!
//! Emits `BENCH_sharding.json` (the `split` and `frontier_batch` keys
//! are schema-additive — the CI gate reads `configs` only, like PR 4's
//! `skewed` key):
//!
//! ```json
//! {"bench":"shard_router","requests":256,"batch_delay_ms":2,
//!  "configs":[{"peers":0,"req_per_s":...,"remote_share":0.0,
//!              "p95_ms":...}, ...],
//!  "split":{"requests":128,"req_per_s":...,"split_share":...,
//!           "p95_ms":...},
//!  "frontier_batch":{"requests":16,
//!                    "window_on":{"req_per_s":...,"p95_ms":...,
//!                                 "mean_coalesced":...},
//!                    "window_off":{...}}}
//! ```
//!
//! Run: `cargo bench --bench shard_router`

use std::time::{Duration, Instant};

use anyhow::Result;
use crowdhmtware::coordinator::{
    BatcherConfig, Executor, PoolConfig, ServingPool, ShardRouter, ShardRouterConfig, Submission,
};
use crowdhmtware::partition::SharedLink;
use crowdhmtware::runtime::SegmentedExec;
use crowdhmtware::util::{Json, Table};

const CLASSES: usize = 4;
const ELEMS: usize = 1024;
const REQUESTS: usize = 256;
const BATCH_DELAY: Duration = Duration::from_millis(2);

struct MockExec;

impl Executor for MockExec {
    fn batch_sizes(&self, _v: &str) -> Vec<usize> {
        vec![1]
    }

    fn num_classes(&self) -> usize {
        CLASSES
    }

    fn input_elems(&self) -> usize {
        ELEMS
    }

    fn run(&mut self, _v: &str, batch: usize, _input: &[f32]) -> Result<Vec<f32>> {
        std::thread::sleep(BATCH_DELAY);
        Ok(vec![1.0 / CLASSES as f32; batch * CLASSES])
    }
}

struct ConfigResult {
    peers: usize,
    req_per_s: f64,
    remote_share: f64,
    p95_ms: f64,
}

fn run_config(peers: usize) -> ConfigResult {
    let pool = ServingPool::spawn(
        |_| Box::new(MockExec) as Box<dyn Executor>,
        "v",
        PoolConfig {
            workers: 2,
            queue_capacity: REQUESTS,
            batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_micros(100) },
            ..PoolConfig::default()
        },
    );
    let router = ShardRouter::new(
        pool,
        ShardRouterConfig {
            peer_capacity: REQUESTS,
            local_prior_s: BATCH_DELAY.as_secs_f64(),
            ..ShardRouterConfig::default()
        },
    );
    for p in 0..peers {
        router.add_simulated_peer(
            &format!("peer-{p}"),
            || Box::new(MockExec) as Box<dyn Executor>,
            SharedLink::new(200.0, 1.0),
            BATCH_DELAY.as_secs_f64(),
        );
    }
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..REQUESTS)
        .map(|_| {
            router.submit_with(Submission::new(vec![0.0; ELEMS]))
                .expect("capacity sized to the run")
        })
        .collect();
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(60)).expect("response");
    }
    let wall = t0.elapsed().as_secs_f64();
    let shard = router.shard_stats();
    let remote = shard.routed_remote();
    let stats = router.shutdown();
    assert_eq!(stats.served(), REQUESTS);
    ConfigResult {
        peers,
        req_per_s: REQUESTS as f64 / wall,
        remote_share: remote as f64 / REQUESTS as f64,
        p95_ms: stats.percentile(0.95) * 1e3,
    }
}

// ── segment-streaming scenario ────────────────────────────────────────

const SPLIT_REQUESTS: usize = 128;

struct SplitResult {
    req_per_s: f64,
    split_share: f64,
    p95_ms: f64,
}

/// Two-segment chain: `head_ms` then `tail_ms`, with a 64-element
/// (256 B) frontier at the cut over the 4 KB input.
fn chain(head_ms: u64, tail_ms: u64) -> SegmentedExec {
    SegmentedExec::new(
        CLASSES,
        vec![ELEMS, 64, CLASSES],
        vec![Duration::from_millis(head_ms), Duration::from_millis(tail_ms)],
    )
}

/// Local tail is 10 ms; the peer runs it in 1 ms; the 8 Mbit/s link
/// affords the frontier (~0.75 ms) but not the input (~4.6 ms). The
/// router should stream most traffic through `split@1`.
fn run_split_scenario() -> SplitResult {
    let pool = ServingPool::spawn(
        |_| Box::new(chain(1, 10)) as Box<dyn Executor>,
        "v",
        PoolConfig {
            workers: 2,
            queue_capacity: SPLIT_REQUESTS,
            batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_micros(100) },
            ..PoolConfig::default()
        },
    );
    let router = ShardRouter::new(
        pool,
        ShardRouterConfig {
            peer_capacity: SPLIT_REQUESTS,
            local_prior_s: 0.011,
            ..ShardRouterConfig::default()
        },
    );
    router.add_simulated_peer(
        "edge",
        || Box::new(chain(5, 1)) as Box<dyn Executor>,
        SharedLink::new(8.0, 1.0),
        0.011,
    );
    router.seed_split(0, 1, 0.003);
    // The peer thread publishes its segment capability asynchronously;
    // wait so the whole run sees the split route.
    for _ in 0..500 {
        if router.admitted_splits() == 1 {
            break;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..SPLIT_REQUESTS)
        .map(|_| {
            router.submit_with(Submission::new(vec![0.0; ELEMS]))
                .expect("capacity sized to the run")
        })
        .collect();
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(60)).expect("response");
    }
    let wall = t0.elapsed().as_secs_f64();
    let split = router.shard_stats().split_routed();
    let stats = router.shutdown();
    assert_eq!(stats.served(), SPLIT_REQUESTS);
    SplitResult {
        req_per_s: SPLIT_REQUESTS as f64 / wall,
        split_share: split as f64 / SPLIT_REQUESTS as f64,
        p95_ms: stats.percentile(0.95) * 1e3,
    }
}

// ── frontier-coalescing scenario ──────────────────────────────────────

const FRONTIER_REQUESTS: usize = 16;

/// A peer transport that *sleeps* its link transfers instead of
/// returning them analytically: with modeled transfers the router's
/// wall clock never contains the round trips the window amortizes, so
/// only a wall-clock transport can show the coalescing win as measured
/// throughput. Transfers therefore report `0.0` analytic seconds — the
/// cost is already in the wall time, like a real network transport.
struct WallClockPeer {
    exec: SegmentedExec,
    link: SharedLink,
}

impl WallClockPeer {
    fn sleep_transfer(&self, bytes: usize) {
        std::thread::sleep(Duration::from_secs_f64(self.link.delay_s(bytes)));
    }
}

impl crowdhmtware::coordinator::PeerTransport for WallClockPeer {
    fn num_classes(&self) -> usize {
        self.exec.classes()
    }

    fn infer(&mut self, _variant: &str, input: &[f32]) -> Result<(Vec<f32>, f64)> {
        self.sleep_transfer(std::mem::size_of_val(input));
        let probs = self.exec.run_range(0, self.exec.segments(), input)?;
        self.sleep_transfer(std::mem::size_of_val(&probs[..]));
        Ok((probs, 0.0))
    }

    fn num_segments(&self) -> usize {
        self.exec.segments()
    }

    fn infer_segments(
        &mut self,
        _variant: &str,
        first_seg: usize,
        input_frontier: &[f32],
    ) -> Result<(Vec<f32>, f64)> {
        self.sleep_transfer(std::mem::size_of_val(input_frontier));
        let probs = self.exec.run_range(first_seg, self.exec.segments(), input_frontier)?;
        self.sleep_transfer(std::mem::size_of_val(&probs[..]));
        Ok((probs, 0.0))
    }

    fn infer_segments_batch(
        &mut self,
        _variant: &str,
        first_seg: usize,
        rows: usize,
        frontiers: &[f32],
    ) -> Result<(Vec<f32>, f64)> {
        // One transfer each way for the whole stack — the amortization
        // the window exists to buy.
        self.sleep_transfer(std::mem::size_of_val(frontiers));
        let per = frontiers.len() / rows.max(1);
        let mut out = Vec::with_capacity(rows * self.exec.classes());
        for row in frontiers.chunks_exact(per) {
            out.extend(self.exec.run_range(first_seg, self.exec.segments(), row)?);
        }
        self.sleep_transfer(std::mem::size_of_val(&out[..]));
        Ok((out, 0.0))
    }

    fn link_profile(&self) -> Option<(f64, f64)> {
        Some((self.link.rtt_s(), self.link.bytes_per_s()))
    }
}

struct FrontierResult {
    req_per_s: f64,
    p95_ms: f64,
    mean_coalesced: f64,
}

/// High-delay link (30 ms RTT), concurrent split burst: with the window
/// off each frontier pays the full round trip alone (~32 ms serialized
/// on the link thread); with the window on, stacked frontiers share it.
fn run_frontier_scenario(window_on: bool) -> FrontierResult {
    let pool = ServingPool::spawn(
        |_| Box::new(chain(1, 10)) as Box<dyn Executor>,
        "v",
        PoolConfig {
            workers: 1,
            queue_capacity: FRONTIER_REQUESTS,
            batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_micros(100) },
            ..PoolConfig::default()
        },
    );
    let router = ShardRouter::new(
        pool,
        ShardRouterConfig {
            peer_capacity: FRONTIER_REQUESTS,
            local_prior_s: 10.0, // the split route must take the whole burst
            probe_every: 0,
            ..ShardRouterConfig::default()
        },
    );
    let link = SharedLink::new(50.0, 30.0);
    let peer_link = link.clone();
    router.add_peer(
        "far-edge",
        move || Box::new(WallClockPeer { exec: chain(5, 1), link: peer_link }),
        0.003,
    );
    router.seed_split(0, 1, 0.003);
    for _ in 0..500 {
        if router.admitted_splits() == 1 {
            break;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    if window_on {
        router.set_frontier_window(0, 8, Duration::from_millis(10));
    } else {
        router.set_frontier_window(0, 1, Duration::ZERO);
    }
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..FRONTIER_REQUESTS)
        .map(|_| {
            router.submit_with(Submission::new(vec![0.0; ELEMS]))
                .expect("capacity sized to the run")
        })
        .collect();
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(60)).expect("response");
    }
    let wall = t0.elapsed().as_secs_f64();
    let shard = router.shard_stats();
    let (batches, coalesced) =
        (shard.peers[0].frontier_batches, shard.peers[0].frontier_coalesced);
    let stats = router.shutdown();
    assert_eq!(stats.served(), FRONTIER_REQUESTS);
    FrontierResult {
        req_per_s: FRONTIER_REQUESTS as f64 / wall,
        p95_ms: stats.percentile(0.95) * 1e3,
        mean_coalesced: if batches > 0 { coalesced as f64 / batches as f64 } else { 0.0 },
    }
}

fn main() {
    let mut table = Table::new(
        "Serving throughput vs attached peers (mock executors, 2 ms/batch)",
        &["peers", "req/s", "remote share", "p95 ms"],
    );
    let mut results = Vec::new();
    for peers in [0usize, 1, 2] {
        let r = run_config(peers);
        table.row(&[
            r.peers.to_string(),
            format!("{:.0}", r.req_per_s),
            format!("{:.2}", r.remote_share),
            format!("{:.2}", r.p95_ms),
        ]);
        results.push(r);
    }
    table.print();

    let split = run_split_scenario();
    let mut split_table = Table::new(
        "Segment streaming (2-seg chain, 10 ms local tail vs 1 ms remote, 8 Mbit/s link)",
        &["req/s", "split share", "p95 ms"],
    );
    split_table.row(&[
        format!("{:.0}", split.req_per_s),
        format!("{:.2}", split.split_share),
        format!("{:.2}", split.p95_ms),
    ]);
    split_table.print();

    let frontier_off = run_frontier_scenario(false);
    let frontier_on = run_frontier_scenario(true);
    let mut frontier_table = Table::new(
        "Frontier coalescing (30 ms RTT wall-clock link, 16 concurrent split requests)",
        &["window", "req/s", "p95 ms", "mean coalesced"],
    );
    for (label, r) in [("off", &frontier_off), ("on", &frontier_on)] {
        frontier_table.row(&[
            label.to_string(),
            format!("{:.0}", r.req_per_s),
            format!("{:.2}", r.p95_ms),
            format!("{:.2}", r.mean_coalesced),
        ]);
    }
    frontier_table.print();
    // The acceptance bar of the coalescing scenario: amortizing the
    // round trips must show up as measured throughput.
    assert!(
        frontier_on.req_per_s > frontier_off.req_per_s,
        "frontier batching must beat per-request serving: {:.0} vs {:.0} req/s",
        frontier_on.req_per_s,
        frontier_off.req_per_s
    );

    let configs: Vec<Json> = results
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("peers", Json::num(r.peers as f64)),
                ("req_per_s", Json::num(r.req_per_s)),
                ("remote_share", Json::num(r.remote_share)),
                ("p95_ms", Json::num(r.p95_ms)),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("bench", Json::str("shard_router")),
        ("requests", Json::num(REQUESTS as f64)),
        ("batch_delay_ms", Json::num(BATCH_DELAY.as_secs_f64() * 1e3)),
        ("configs", Json::Arr(configs)),
        // Schema-additive (like PR 4's `skewed` key in BENCH_serving):
        // the CI gate reads `configs` only, so recording the split
        // trajectory cannot affect existing gates.
        (
            "split",
            Json::obj(vec![
                ("requests", Json::num(SPLIT_REQUESTS as f64)),
                ("req_per_s", Json::num(split.req_per_s)),
                ("split_share", Json::num(split.split_share)),
                ("p95_ms", Json::num(split.p95_ms)),
            ]),
        ),
        // Schema-additive like `split`: the window-on/off comparison of
        // the coalescing scenario, invisible to the existing gate.
        (
            "frontier_batch",
            Json::obj(vec![
                ("requests", Json::num(FRONTIER_REQUESTS as f64)),
                (
                    "window_on",
                    Json::obj(vec![
                        ("req_per_s", Json::num(frontier_on.req_per_s)),
                        ("p95_ms", Json::num(frontier_on.p95_ms)),
                        ("mean_coalesced", Json::num(frontier_on.mean_coalesced)),
                    ]),
                ),
                (
                    "window_off",
                    Json::obj(vec![
                        ("req_per_s", Json::num(frontier_off.req_per_s)),
                        ("p95_ms", Json::num(frontier_off.p95_ms)),
                        ("mean_coalesced", Json::num(frontier_off.mean_coalesced)),
                    ]),
                ),
            ]),
        ),
    ]);
    let path = "BENCH_sharding.json";
    match std::fs::write(path, doc.to_string() + "\n") {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
