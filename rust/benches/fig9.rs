//! Regenerates Fig. 9 (cross-device comparison vs AdaDeep).
fn main() {
    let rows = crowdhmtware::experiments::fig9::run();
    crowdhmtware::experiments::fig9::table(&rows).print();
}
