//! §Perf micro-bench harness for the L3 hot paths (no criterion in this
//! offline environment — plain wall-clock loops with warmup, median of
//! repeated runs).
//!
//! Hot paths measured:
//!   profiler  — one Eq. 1/2 evaluation (runs every adaptation tick)
//!   fusion    — full fusion pass over ResNet18
//!   memalloc  — lifetime analysis + arena packing
//!   offload   — pre-partition + DP offload planning
//!   tick      — one full adaptation-loop tick (4-candidate front)
//!   batcher   — push+pop of an 8-request batch

use std::time::Instant;

use crowdhmtware::compress::{OperatorKind, VariantSpec};
use crowdhmtware::coordinator::{Batcher, BatcherConfig, Request};
use crowdhmtware::device::{device, ResourceMonitor};
use crowdhmtware::engine::{allocate, fuse, EngineConfig, FusionConfig};
use crowdhmtware::graph::CostProfile;
use crowdhmtware::models::{resnet18, ResNetStyle};
use crowdhmtware::optimizer::{AdaptLoop, Budgets, Candidate};
use crowdhmtware::partition::{plan_offload, prepartition, DeviceState, Topology};
use crowdhmtware::profiler::{estimate_energy, estimate_latency};

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    // Warmup.
    for _ in 0..3 {
        f();
    }
    let mut samples = Vec::with_capacity(5);
    for _ in 0..5 {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        samples.push(t0.elapsed().as_secs_f64() / iters as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let med = samples[2];
    println!("{name:<22} {:>12.1} µs/iter  ({iters} iters, median of 5)", med * 1e6);
}

fn main() {
    let g = resnet18(ResNetStyle::Cifar, 100, 1);
    let snap = ResourceMonitor::new(device("raspberrypi-4b").unwrap()).idle_snapshot();
    let cost = CostProfile::of(&g);

    println!("== hotpath micro-benchmarks (L3) ==");
    bench("profiler eval", 200, || {
        let l = estimate_latency(&cost, &snap);
        let e = estimate_energy(&cost, &snap);
        std::hint::black_box((l.total_s, e.total_j));
    });
    bench("cost profile", 200, || {
        std::hint::black_box(CostProfile::of(&g).total_macs());
    });
    bench("fusion pass", 100, || {
        std::hint::black_box(fuse(&g, FusionConfig::all()).0.len());
    });
    bench("memalloc", 100, || {
        std::hint::black_box(allocate(&g).arena_bytes);
    });
    let pp = prepartition(&g);
    let topo = Topology::wifi_pair("raspberrypi-4b", "jetson-nx");
    let devs = vec![
        DeviceState { snap: snap.clone(), mem_budget: 4e9 },
        DeviceState {
            snap: ResourceMonitor::new(device("jetson-nx").unwrap()).idle_snapshot(),
            mem_budget: 8e9,
        },
    ];
    bench("prepartition", 100, || {
        std::hint::black_box(prepartition(&g).cuts.len());
    });
    bench("offload DP", 100, || {
        std::hint::black_box(plan_offload(&g, &pp, &devs, &topo).latency_s);
    });
    let front = vec![
        Candidate::baseline(),
        Candidate { engine: EngineConfig::all(), ..Candidate::baseline() },
        Candidate {
            spec: VariantSpec::single(OperatorKind::ChannelScale, 0.5),
            engine: EngineConfig::all(),
            offload: false,
        },
        Candidate {
            spec: VariantSpec::pair((OperatorKind::LowRank, 0.25), (OperatorKind::ChannelScale, 0.5)),
            engine: EngineConfig::all(),
            offload: false,
        },
    ];
    let mut l = AdaptLoop::new(g.clone(), 76.23, front, Budgets::unconstrained());
    bench("adapt tick", 20, || {
        std::hint::black_box(matches!(l.tick(&snap), crowdhmtware::optimizer::Decision::Hold));
    });
    // One response channel shared across iterations: the bench measures
    // batcher push/pop, not channel construction.
    let (resp, _resp_rx) = std::sync::mpsc::channel();
    bench("batcher 8", 1000, || {
        let mut b = Batcher::new(BatcherConfig::default());
        let now = Instant::now();
        for i in 0..8 {
            let req = Request {
                id: i,
                input: vec![0.0; 16],
                enqueued: now,
                lane: crowdhmtware::telemetry::Lane::Normal,
                resp: resp.clone(),
            };
            b.push(req);
        }
        std::hint::black_box(b.pop_batch(&[1, 8], now).map(|x| x.compiled_batch));
    });
}
