//! §Perf micro-bench harness for the L3 hot paths (no criterion in this
//! offline environment — plain wall-clock loops with warmup, median of
//! repeated runs).
//!
//! Hot paths measured:
//!   profiler  — one Eq. 1/2 evaluation (runs every adaptation tick)
//!   fusion    — full fusion pass over ResNet18
//!   memalloc  — lifetime analysis + arena packing
//!   offload   — pre-partition + DP offload planning
//!   tick      — one full adaptation-loop tick (4-candidate front)
//!   batcher   — push+pop of an 8-request batch
//!
//! Plus two end-to-end *submit-path* scenarios through a live pool:
//!
//!   submit_unique     — a burst of all-distinct inputs (the zero-copy
//!                       admission + per-worker padding-scratch path)
//!   submit_hot_cached — a burst of *identical* inputs against the
//!                       single-flight response cache: the whole burst
//!                       collapses onto ~one inference, every other
//!                       caller answered by a hit or an in-flight join
//!
//! The run emits `BENCH_hotpath.json` so the submit-path trajectory is
//! machine-readable across PRs (gated by `ci/check_bench.py` against
//! `ci/BENCH_hotpath_baseline.json`; the string-keyed `scenarios` array
//! is the gated entry set, `cache` and `micro` are additive):
//!
//! ```json
//! {"bench":"hotpath","requests":256,
//!  "scenarios":[{"name":"submit_unique","req_per_s":...,"p95_ms":...},
//!               {"name":"submit_hot_cached","req_per_s":...,"p95_ms":...}],
//!  "cache":{"served":...,"hits":...,"coalesced":...},
//!  "micro":{"batcher_8_us":..., ...}}
//! ```
//!
//! Run: `cargo bench --bench hotpath`

use std::time::{Duration, Instant};

use anyhow::Result;
use crowdhmtware::compress::{OperatorKind, VariantSpec};
use crowdhmtware::coordinator::{
    Batcher, BatcherConfig, CacheConfig, Executor, PoolConfig, Request, ServingPool, Submission,
};
use crowdhmtware::device::{device, ResourceMonitor};
use crowdhmtware::engine::{allocate, fuse, EngineConfig, FusionConfig};
use crowdhmtware::graph::CostProfile;
use crowdhmtware::models::{resnet18, ResNetStyle};
use crowdhmtware::optimizer::{AdaptLoop, Budgets, Candidate};
use crowdhmtware::partition::{plan_offload, prepartition, DeviceState, Topology};
use crowdhmtware::profiler::{estimate_energy, estimate_latency};
use crowdhmtware::util::Json;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    // Warmup.
    for _ in 0..3 {
        f();
    }
    let mut samples = Vec::with_capacity(5);
    for _ in 0..5 {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        samples.push(t0.elapsed().as_secs_f64() / iters as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let med = samples[2];
    println!("{name:<22} {:>12.1} µs/iter  ({iters} iters, median of 5)", med * 1e6);
    med
}

// ── submit-path scenarios ──────────────────────────────────────────────

const CLASSES: usize = 4;
const ELEMS: usize = 16;
const SUBMIT_REQUESTS: usize = 256;
const BATCH_DELAY: Duration = Duration::from_millis(2);

struct BenchExec;

impl Executor for BenchExec {
    fn batch_sizes(&self, _v: &str) -> Vec<usize> {
        vec![1, 4, 8]
    }

    fn num_classes(&self) -> usize {
        CLASSES
    }

    fn input_elems(&self) -> usize {
        ELEMS
    }

    fn run(&mut self, _v: &str, batch: usize, _input: &[f32]) -> Result<Vec<f32>> {
        std::thread::sleep(BATCH_DELAY);
        Ok(vec![1.0 / CLASSES as f32; batch * CLASSES])
    }
}

struct Scenario {
    name: &'static str,
    req_per_s: f64,
    p95_ms: f64,
}

struct CacheCounters {
    served: usize,
    hits: usize,
    coalesced: usize,
}

fn submit_pool(cache: CacheConfig) -> ServingPool {
    ServingPool::spawn(
        |_| Box::new(BenchExec) as Box<dyn Executor>,
        "v",
        PoolConfig {
            workers: 2,
            queue_capacity: SUBMIT_REQUESTS,
            batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_micros(500) },
            cache,
            ..PoolConfig::default()
        },
    )
}

/// A burst of all-distinct inputs: measures the zero-copy admission and
/// per-worker padding-scratch path with no cache interference.
fn run_submit_unique() -> Scenario {
    let pool = submit_pool(CacheConfig::default());
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..SUBMIT_REQUESTS)
        .map(|i| {
            let mut input = vec![0.0f32; ELEMS];
            input[0] = i as f32; // every request a distinct buffer
            pool.submit_with(Submission::new(input)).expect("capacity sized to the run")
        })
        .collect();
    // Variant names are interned: every response clones one `Arc<str>`
    // allocation made at spawn/switch time, never a per-response String.
    let mut first_variant: Option<std::sync::Arc<str>> = None;
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(60)).expect("response");
        match &first_variant {
            None => first_variant = Some(std::sync::Arc::clone(&resp.variant)),
            Some(v) => assert!(
                std::sync::Arc::ptr_eq(v, &resp.variant),
                "per-response variant allocation on the hot path"
            ),
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = pool.shutdown();
    assert_eq!(stats.served(), SUBMIT_REQUESTS);
    let p95 = stats.merged().percentiles(&[0.95])[0];
    Scenario {
        name: "submit_unique",
        req_per_s: SUBMIT_REQUESTS as f64 / wall,
        p95_ms: p95 * 1e3,
    }
}

/// A burst of *identical* inputs against the single-flight cache: one
/// leader pays the inference, concurrent identical submissions join its
/// flight, later ones hit the completed entry — N callers, ~1 batch.
fn run_submit_hot_cached() -> (Scenario, CacheCounters) {
    let pool = submit_pool(CacheConfig { enabled: true, capacity: 64, ..CacheConfig::default() });
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..SUBMIT_REQUESTS)
        .map(|_| {
            pool.submit_with(Submission::new(vec![0.5f32; ELEMS]))
                .expect("capacity sized to the run")
        })
        .collect();
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(60)).expect("response");
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = pool.telemetry_snapshot();
    let stats = pool.shutdown();
    // The acceptance bar for the cache: repeated identical inputs cost
    // ~one inference for the whole burst, with the other callers
    // accounted as hits or in-flight joins.
    assert!(
        stats.served() < SUBMIT_REQUESTS / 8,
        "hot-input burst must collapse: served {} of {}",
        stats.served(),
        SUBMIT_REQUESTS
    );
    assert_eq!(
        snap.cache_hits + snap.cache_inflight_coalesced + stats.served(),
        SUBMIT_REQUESTS,
        "every caller is a leader, a hit, or a join"
    );
    // Latency percentiles only sample executed requests; cached callers
    // return without touching a worker, so report wall-derived p95 as 0
    // only if nothing executed (never: the leader always runs).
    let p95 = stats.merged().percentiles(&[0.95])[0];
    (
        Scenario {
            name: "submit_hot_cached",
            req_per_s: SUBMIT_REQUESTS as f64 / wall,
            p95_ms: p95 * 1e3,
        },
        CacheCounters {
            served: stats.served(),
            hits: snap.cache_hits,
            coalesced: snap.cache_inflight_coalesced,
        },
    )
}

fn main() {
    let g = resnet18(ResNetStyle::Cifar, 100, 1);
    let snap = ResourceMonitor::new(device("raspberrypi-4b").unwrap()).idle_snapshot();
    let cost = CostProfile::of(&g);

    println!("== hotpath micro-benchmarks (L3) ==");
    let mut micro: Vec<(&str, f64)> = Vec::new();
    micro.push((
        "profiler_eval_us",
        bench("profiler eval", 200, || {
            let l = estimate_latency(&cost, &snap);
            let e = estimate_energy(&cost, &snap);
            std::hint::black_box((l.total_s, e.total_j));
        }) * 1e6,
    ));
    micro.push((
        "cost_profile_us",
        bench("cost profile", 200, || {
            std::hint::black_box(CostProfile::of(&g).total_macs());
        }) * 1e6,
    ));
    micro.push((
        "fusion_pass_us",
        bench("fusion pass", 100, || {
            std::hint::black_box(fuse(&g, FusionConfig::all()).0.len());
        }) * 1e6,
    ));
    micro.push((
        "memalloc_us",
        bench("memalloc", 100, || {
            std::hint::black_box(allocate(&g).arena_bytes);
        }) * 1e6,
    ));
    let pp = prepartition(&g);
    let topo = Topology::wifi_pair("raspberrypi-4b", "jetson-nx");
    let devs = vec![
        DeviceState { snap: snap.clone(), mem_budget: 4e9 },
        DeviceState {
            snap: ResourceMonitor::new(device("jetson-nx").unwrap()).idle_snapshot(),
            mem_budget: 8e9,
        },
    ];
    micro.push((
        "prepartition_us",
        bench("prepartition", 100, || {
            std::hint::black_box(prepartition(&g).cuts.len());
        }) * 1e6,
    ));
    micro.push((
        "offload_dp_us",
        bench("offload DP", 100, || {
            std::hint::black_box(plan_offload(&g, &pp, &devs, &topo).latency_s);
        }) * 1e6,
    ));
    let front = vec![
        Candidate::baseline(),
        Candidate { engine: EngineConfig::all(), ..Candidate::baseline() },
        Candidate {
            spec: VariantSpec::single(OperatorKind::ChannelScale, 0.5),
            engine: EngineConfig::all(),
            offload: false,
        },
        Candidate {
            spec: VariantSpec::pair((OperatorKind::LowRank, 0.25), (OperatorKind::ChannelScale, 0.5)),
            engine: EngineConfig::all(),
            offload: false,
        },
    ];
    let mut l = AdaptLoop::new(g.clone(), 76.23, front, Budgets::unconstrained());
    micro.push((
        "adapt_tick_us",
        bench("adapt tick", 20, || {
            std::hint::black_box(matches!(l.tick(&snap), crowdhmtware::optimizer::Decision::Hold));
        }) * 1e6,
    ));
    // One response channel shared across iterations: the bench measures
    // batcher push/pop, not channel construction. The input Arc is also
    // shared — pushing a request moves a pointer, mirroring production.
    let (resp, _resp_rx) = std::sync::mpsc::channel();
    let shared_input: std::sync::Arc<[f32]> = vec![0.0f32; ELEMS].into();
    micro.push((
        "batcher_8_us",
        bench("batcher 8", 1000, || {
            let mut b = Batcher::new(BatcherConfig::default());
            let now = Instant::now();
            for i in 0..8 {
                let req = Request {
                    id: i,
                    input: std::sync::Arc::clone(&shared_input),
                    enqueued: now,
                    lane: crowdhmtware::telemetry::Lane::Normal,
                    resp: resp.clone(),
                    cache: None,
                };
                b.push(req);
            }
            std::hint::black_box(b.pop_batch(&[1, 8], now).map(|x| x.compiled_batch));
        }) * 1e6,
    ));

    println!("\n== submit-path scenarios (2 workers, 2 ms/batch) ==");
    let unique = run_submit_unique();
    let (hot, counters) = run_submit_hot_cached();
    for s in [&unique, &hot] {
        println!("{:<20} {:>8.0} req/s   p95 {:>7.2} ms", s.name, s.req_per_s, s.p95_ms);
    }
    println!(
        "cache: served {} of {SUBMIT_REQUESTS} (hits {}, in-flight joins {})",
        counters.served, counters.hits, counters.coalesced
    );

    // Machine-readable trajectory for cross-PR comparison.
    let scenarios: Vec<Json> = [&unique, &hot]
        .iter()
        .map(|s| {
            Json::obj(vec![
                ("name", Json::str(s.name)),
                ("req_per_s", Json::num(s.req_per_s)),
                ("p95_ms", Json::num(s.p95_ms)),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("bench", Json::str("hotpath")),
        ("requests", Json::num(SUBMIT_REQUESTS as f64)),
        ("scenarios", Json::Arr(scenarios)),
        (
            "cache",
            Json::obj(vec![
                ("served", Json::num(counters.served as f64)),
                ("hits", Json::num(counters.hits as f64)),
                ("coalesced", Json::num(counters.coalesced as f64)),
            ]),
        ),
        (
            "micro",
            Json::obj(micro.iter().map(|&(k, v)| (k, Json::num(v))).collect()),
        ),
    ]);
    let path = "BENCH_hotpath.json";
    match std::fs::write(path, doc.to_string() + "\n") {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
