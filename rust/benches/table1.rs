//! Regenerates Table I (12-device normalized gains).
fn main() {
    let rows = crowdhmtware::experiments::table1::run();
    crowdhmtware::experiments::table1::table(&rows).print();
}
