//! Pool-vs-single-worker serving throughput on the mock executor (no
//! criterion in this offline environment — plain wall-clock runs).
//!
//! Each batch costs a fixed wall-clock delay, modeling a PJRT dispatch:
//! a single worker is bounded by `batches × delay`, while the pool
//! overlaps batches across workers. Reported per pool width: sustained
//! req/s, pool p50/p95/p99 latency, mean batch occupancy, rejections.
//!
//! A second, *skewed* scenario measures the work-stealing path: one
//! worker is wedged on slow batches with its queue pre-loaded, then fast
//! idle workers join. With stealing on they drain the stranded backlog;
//! with stealing off the backlog serializes behind the wedge. Both runs
//! are reported so the head-of-line win stays visible across PRs.
//!
//! Besides the human-readable table, the run emits `BENCH_serving.json`
//! (schema below) so the repo's serving-performance trajectory stays
//! machine-readable across PRs:
//!
//! ```json
//! {"bench":"serving_pool","requests":512,"batch_delay_ms":1,
//!  "widths":[{"workers":1,"req_per_s":...,"p50_ms":...,"p95_ms":...,
//!             "p99_ms":...,"mean_batch":...,"rejected":0}, ...],
//!  "best":{"workers":8,"req_per_s":...,"speedup_vs_single":...},
//!  "skewed":{"preload":64,"slow_batch_ms":20,
//!            "configs":[{"steal":1,"wall_ms":...,"steals":...}, ...]},
//!  "cache":{"hot_requests":256,
//!           "configs":[{"enabled":1,"wall_ms":...,"served":...,
//!                       "hits":...,"coalesced":...}, ...]}}
//! ```
//!
//! The `cache` key (hot-input burst, single-flight cache on vs off) is
//! schema-additive: `ci/check_bench.py` pairs on `widths` and ignores it.
//!
//! Run: `cargo bench --bench serving_pool`

use std::time::{Duration, Instant};

use anyhow::Result;
use crowdhmtware::coordinator::{
    BatcherConfig, CacheConfig, Executor, PoolConfig, ServingPool, StealConfig, Submission,
};
use crowdhmtware::util::{Json, Table};

const CLASSES: usize = 4;
const ELEMS: usize = 16;
const REQUESTS: usize = 512;
const BATCH_DELAY: Duration = Duration::from_millis(1);

struct MockExec;

impl Executor for MockExec {
    fn batch_sizes(&self, _v: &str) -> Vec<usize> {
        vec![1, 4, 8]
    }

    fn num_classes(&self) -> usize {
        CLASSES
    }

    fn input_elems(&self) -> usize {
        ELEMS
    }

    fn run(&mut self, _v: &str, batch: usize, _input: &[f32]) -> Result<Vec<f32>> {
        std::thread::sleep(BATCH_DELAY);
        Ok(vec![1.0 / CLASSES as f32; batch * CLASSES])
    }
}

struct WidthResult {
    workers: usize,
    req_per_s: f64,
    p50_ms: f64,
    p95_ms: f64,
    p99_ms: f64,
    mean_batch: f64,
    rejected: usize,
}

fn run_width(workers: usize) -> WidthResult {
    let pool = ServingPool::spawn(
        |_| Box::new(MockExec) as Box<dyn Executor>,
        "v",
        PoolConfig {
            workers,
            queue_capacity: REQUESTS,
            batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_micros(500) },
            ..PoolConfig::default()
        },
    );
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..REQUESTS)
        .map(|_| {
            pool.submit_with(Submission::new(vec![0.0; ELEMS])).expect("capacity sized to the run")
        })
        .collect();
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(60)).expect("response");
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = pool.shutdown();
    assert_eq!(stats.served(), REQUESTS);
    let merged = stats.merged();
    // One sorted scratch serves all three quantiles (see
    // `ServingStats::percentiles`) instead of three clone+sort passes.
    let ps = merged.percentiles(&[0.5, 0.95, 0.99]);
    WidthResult {
        workers,
        req_per_s: REQUESTS as f64 / wall,
        p50_ms: ps[0] * 1e3,
        p95_ms: ps[1] * 1e3,
        p99_ms: ps[2] * 1e3,
        mean_batch: merged.mean_batch_size(),
        rejected: stats.rejected(),
    }
}

const SKEW_PRELOAD: usize = 64;
const SLOW_BATCH: Duration = Duration::from_millis(20);

/// Slow executor for worker 0, fast for dynamically spawned workers —
/// the wedged-victim topology.
struct SkewExec {
    delay: Duration,
}

impl Executor for SkewExec {
    fn batch_sizes(&self, _v: &str) -> Vec<usize> {
        vec![1]
    }

    fn num_classes(&self) -> usize {
        CLASSES
    }

    fn input_elems(&self) -> usize {
        ELEMS
    }

    fn run(&mut self, _v: &str, batch: usize, _input: &[f32]) -> Result<Vec<f32>> {
        std::thread::sleep(self.delay);
        Ok(vec![1.0 / CLASSES as f32; batch * CLASSES])
    }
}

struct SkewedResult {
    steal: bool,
    wall_ms: f64,
    steals: usize,
}

/// Pre-load a single slow worker, then grow the pool with fast idle
/// workers and measure how long the stranded backlog takes to drain.
fn run_skewed(steal_enabled: bool) -> SkewedResult {
    let pool = ServingPool::spawn(
        |worker| {
            let delay = if worker == 0 { SLOW_BATCH } else { Duration::from_millis(1) };
            Box::new(SkewExec { delay }) as Box<dyn Executor>
        },
        "v",
        PoolConfig {
            workers: 1,
            queue_capacity: 2 * SKEW_PRELOAD,
            batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_micros(100) },
            steal: StealConfig { enabled: steal_enabled, ..StealConfig::default() },
            ..PoolConfig::default()
        },
    );
    let t0 = Instant::now();
    let wedge =
        pool.submit_with(Submission::new(vec![0.0; ELEMS])).expect("capacity sized to the run");
    std::thread::sleep(Duration::from_millis(5)); // let the wedge batch start
    let rxs: Vec<_> = (0..SKEW_PRELOAD)
        .map(|_| {
            pool.submit_with(Submission::new(vec![0.0; ELEMS])).expect("capacity sized to the run")
        })
        .collect();
    pool.set_workers(4);
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(60)).expect("response");
    }
    wedge.recv_timeout(Duration::from_secs(60)).expect("response");
    let wall = t0.elapsed().as_secs_f64();
    let steals = pool.telemetry_snapshot().steals;
    let stats = pool.shutdown();
    assert_eq!(stats.served(), SKEW_PRELOAD + 1);
    SkewedResult { steal: steal_enabled, wall_ms: wall * 1e3, steals }
}

const HOT_REQUESTS: usize = 256;

struct HotResult {
    enabled: bool,
    wall_ms: f64,
    served: usize,
    hits: usize,
    coalesced: usize,
}

/// Hot-input scenario: every request carries the *same* input. With the
/// single-flight cache on, the whole burst collapses onto roughly one
/// inference; off, every request pays a batch slot.
fn run_hot_input(enabled: bool) -> HotResult {
    let pool = ServingPool::spawn(
        |_| Box::new(MockExec) as Box<dyn Executor>,
        "v",
        PoolConfig {
            workers: 2,
            queue_capacity: HOT_REQUESTS,
            batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_micros(500) },
            cache: CacheConfig { enabled, capacity: 64, ..CacheConfig::default() },
            ..PoolConfig::default()
        },
    );
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..HOT_REQUESTS)
        .map(|_| {
            pool.submit_with(Submission::new(vec![0.5; ELEMS])).expect("capacity sized to the run")
        })
        .collect();
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(60)).expect("response");
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = pool.telemetry_snapshot();
    let stats = pool.shutdown();
    HotResult {
        enabled,
        wall_ms: wall * 1e3,
        served: stats.served(),
        hits: snap.cache_hits,
        coalesced: snap.cache_inflight_coalesced,
    }
}

fn main() {
    let mut table = Table::new(
        "Serving throughput vs pool width (mock executor, 1 ms/batch)",
        &["workers", "req/s", "p50 ms", "p95 ms", "p99 ms", "mean batch", "rejected"],
    );
    let mut results = Vec::new();
    for &w in &[1usize, 2, 4, 8] {
        let r = run_width(w);
        table.row(&[
            r.workers.to_string(),
            format!("{:.0}", r.req_per_s),
            format!("{:.2}", r.p50_ms),
            format!("{:.2}", r.p95_ms),
            format!("{:.2}", r.p99_ms),
            format!("{:.1}", r.mean_batch),
            r.rejected.to_string(),
        ]);
        results.push(r);
    }
    table.print();

    let single = results.first().map(|r| r.req_per_s).unwrap_or(0.0);
    let best = results
        .iter()
        .max_by(|a, b| a.req_per_s.partial_cmp(&b.req_per_s).unwrap())
        .expect("at least one width");
    println!(
        "\nbest: {} workers at {:.0} req/s — {:.1}× the single-worker baseline",
        best.workers,
        best.req_per_s,
        if single > 0.0 { best.req_per_s / single } else { 0.0 }
    );

    // Skewed (wedged-victim) scenario: stealing on vs off.
    let mut skew_table = Table::new(
        "Stranded-backlog drain: wedged worker + 3 fast joiners (20 ms vs 1 ms batches)",
        &["steal", "wall ms", "steals"],
    );
    let skewed: Vec<SkewedResult> = vec![run_skewed(true), run_skewed(false)];
    for r in &skewed {
        skew_table.row(&[
            if r.steal { "on".to_string() } else { "off".to_string() },
            format!("{:.0}", r.wall_ms),
            r.steals.to_string(),
        ]);
    }
    skew_table.print();

    // Hot-input scenario: identical requests, cache on vs off.
    let mut hot_table = Table::new(
        "Hot-input burst: 256 identical requests (single-flight cache on vs off)",
        &["cache", "wall ms", "served", "hits", "coalesced"],
    );
    let hot: Vec<HotResult> = vec![run_hot_input(true), run_hot_input(false)];
    for r in &hot {
        hot_table.row(&[
            if r.enabled { "on".to_string() } else { "off".to_string() },
            format!("{:.0}", r.wall_ms),
            r.served.to_string(),
            r.hits.to_string(),
            r.coalesced.to_string(),
        ]);
    }
    hot_table.print();

    // Machine-readable trajectory for cross-PR comparison.
    let widths: Vec<Json> = results
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("workers", Json::num(r.workers as f64)),
                ("req_per_s", Json::num(r.req_per_s)),
                ("p50_ms", Json::num(r.p50_ms)),
                ("p95_ms", Json::num(r.p95_ms)),
                ("p99_ms", Json::num(r.p99_ms)),
                ("mean_batch", Json::num(r.mean_batch)),
                ("rejected", Json::num(r.rejected as f64)),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("bench", Json::str("serving_pool")),
        ("requests", Json::num(REQUESTS as f64)),
        ("batch_delay_ms", Json::num(BATCH_DELAY.as_secs_f64() * 1e3)),
        ("widths", Json::Arr(widths)),
        (
            "best",
            Json::obj(vec![
                ("workers", Json::num(best.workers as f64)),
                ("req_per_s", Json::num(best.req_per_s)),
                (
                    "speedup_vs_single",
                    Json::num(if single > 0.0 { best.req_per_s / single } else { 0.0 }),
                ),
            ]),
        ),
        (
            "skewed",
            Json::obj(vec![
                ("preload", Json::num(SKEW_PRELOAD as f64)),
                ("slow_batch_ms", Json::num(SLOW_BATCH.as_secs_f64() * 1e3)),
                (
                    "configs",
                    Json::Arr(
                        skewed
                            .iter()
                            .map(|r| {
                                Json::obj(vec![
                                    ("steal", Json::num(if r.steal { 1.0 } else { 0.0 })),
                                    ("wall_ms", Json::num(r.wall_ms)),
                                    ("steals", Json::num(r.steals as f64)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
        ),
        // Schema-additive: readers pairing on "widths" ignore this key.
        (
            "cache",
            Json::obj(vec![
                ("hot_requests", Json::num(HOT_REQUESTS as f64)),
                (
                    "configs",
                    Json::Arr(
                        hot.iter()
                            .map(|r| {
                                Json::obj(vec![
                                    ("enabled", Json::num(if r.enabled { 1.0 } else { 0.0 })),
                                    ("wall_ms", Json::num(r.wall_ms)),
                                    ("served", Json::num(r.served as f64)),
                                    ("hits", Json::num(r.hits as f64)),
                                    ("coalesced", Json::num(r.coalesced as f64)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
        ),
    ]);
    let path = "BENCH_serving.json";
    match std::fs::write(path, doc.to_string() + "\n") {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
