//! Pool-vs-single-worker serving throughput on the mock executor (no
//! criterion in this offline environment — plain wall-clock runs).
//!
//! Each batch costs a fixed wall-clock delay, modeling a PJRT dispatch:
//! a single worker is bounded by `batches × delay`, while the pool
//! overlaps batches across workers. Reported per pool width: sustained
//! req/s, pool p50/p99 latency, mean batch occupancy, rejections.
//!
//! Run: `cargo bench --bench serving_pool`

use std::time::{Duration, Instant};

use anyhow::Result;
use crowdhmtware::coordinator::{BatcherConfig, Executor, PoolConfig, ServingPool};
use crowdhmtware::util::Table;

const CLASSES: usize = 4;
const ELEMS: usize = 16;
const REQUESTS: usize = 512;
const BATCH_DELAY: Duration = Duration::from_millis(1);

struct MockExec;

impl Executor for MockExec {
    fn batch_sizes(&self, _v: &str) -> Vec<usize> {
        vec![1, 4, 8]
    }

    fn num_classes(&self) -> usize {
        CLASSES
    }

    fn input_elems(&self) -> usize {
        ELEMS
    }

    fn run(&mut self, _v: &str, batch: usize, _input: &[f32]) -> Result<Vec<f32>> {
        std::thread::sleep(BATCH_DELAY);
        Ok(vec![1.0 / CLASSES as f32; batch * CLASSES])
    }
}

fn run_width(workers: usize) -> (f64, f64, f64, f64, usize) {
    let pool = ServingPool::spawn(
        |_| Box::new(MockExec) as Box<dyn Executor>,
        "v",
        PoolConfig {
            workers,
            queue_capacity: REQUESTS,
            batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_micros(500) },
            ..PoolConfig::default()
        },
    );
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..REQUESTS)
        .map(|_| pool.submit(vec![0.0; ELEMS]).expect("capacity sized to the run"))
        .collect();
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(60)).expect("response");
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = pool.shutdown();
    assert_eq!(stats.served(), REQUESTS);
    let merged = stats.merged();
    (
        REQUESTS as f64 / wall,
        merged.percentile(0.5) * 1e3,
        merged.percentile(0.99) * 1e3,
        merged.mean_batch_size(),
        stats.rejected(),
    )
}

fn main() {
    let mut table = Table::new(
        "Serving throughput vs pool width (mock executor, 1 ms/batch)",
        &["workers", "req/s", "p50 ms", "p99 ms", "mean batch", "rejected"],
    );
    let mut single = 0.0f64;
    let mut best = (1usize, 0.0f64);
    for &w in &[1usize, 2, 4, 8] {
        let (rps, p50, p99, occ, rej) = run_width(w);
        if w == 1 {
            single = rps;
        }
        if rps > best.1 {
            best = (w, rps);
        }
        table.row(&[
            w.to_string(),
            format!("{rps:.0}"),
            format!("{p50:.2}"),
            format!("{p99:.2}"),
            format!("{occ:.1}"),
            rej.to_string(),
        ]);
    }
    table.print();
    println!(
        "\nbest: {} workers at {:.0} req/s — {:.1}× the single-worker baseline",
        best.0,
        best.1,
        if single > 0.0 { best.1 / single } else { 0.0 }
    );
}
