//! Integration tests asserting the *shape* of every reproduced result:
//! who wins, in which direction, and (loosely) by what factor — the
//! contract DESIGN.md sets for the paper's tables and figures.

use crowdhmtware::experiments as ex;

#[test]
fn fig8_crowdhmt_beats_adadeep_on_every_model() {
    let rows = ex::fig8::run("raspberrypi-4b");
    assert_eq!(rows.len(), 3);
    for r in &rows {
        assert!(r.our_acc >= r.ada_acc, "{}: accuracy must not regress", r.model);
        assert!(r.latency_gain() > 1.5, "{}: latency gain {:.2}", r.model, r.latency_gain());
        assert!(r.memory_gain() > 1.5, "{}: memory gain {:.2}", r.model, r.memory_gain());
    }
    // Paper ordering: the heavyweight VGG16 gains the most latency.
    let vgg = rows.iter().find(|r| r.model == "vgg16").unwrap();
    let r18 = rows.iter().find(|r| r.model == "resnet18").unwrap();
    assert!(
        vgg.latency_gain() > r18.latency_gain(),
        "vgg {:.1}x vs resnet18 {:.1}x",
        vgg.latency_gain(),
        r18.latency_gain()
    );
}

#[test]
fn fig9_wins_on_every_device() {
    for r in ex::fig9::run() {
        assert!(r.our_acc >= r.ada_acc, "{}", r.device);
        assert!(r.our_latency_s < r.ada_latency_s, "{}", r.device);
    }
}

#[test]
fn table1_improves_all_12_devices() {
    let rows = ex::table1::run();
    assert_eq!(rows.len(), 12);
    for r in &rows {
        assert!(r.latency_gain > 1.0, "{}: latency {:.2}", r.device, r.latency_gain);
        assert!(r.macs_gain > 1.0, "{}: macs {:.2}", r.device, r.macs_gain);
        assert!(r.energy_gain > 1.0, "{}: energy {:.2}", r.device, r.energy_gain);
        assert!(r.acc_delta > -3.0, "{}: Δacc {:.2}", r.device, r.acc_delta);
    }
}

#[test]
fn table2_memory_tracks_budget_and_accuracy_holds() {
    let rows = ex::table2::run();
    assert_eq!(rows.len(), 4);
    // Memory decreases monotonically with the budget.
    for w in rows.windows(2) {
        assert!(
            w[1].memory_mb <= w[0].memory_mb + 1e-6,
            "{} -> {}",
            w[0].memory_mb,
            w[1].memory_mb
        );
    }
    // 25% budget honoured.
    assert!(rows[3].memory_mb <= rows[0].memory_mb * 0.25 + 1e-6);
    // Accuracy stays within 3 pp of unrestricted (paper: held at 76%).
    for r in &rows {
        assert!(r.accuracy > rows[0].accuracy - 3.0, "{}: {:.2}", r.budget_label, r.accuracy);
    }
    // The extreme 25% budget costs latency vs the 50% state (the paper's
    // swap-induced spike): it must not be the fastest row.
    let min_lat = rows.iter().map(|r| r.latency_s).fold(f64::MAX, f64::min);
    assert!(rows[3].latency_s > min_lat, "25% row should pay a swap penalty");
}

#[test]
fn fig10_crowdhmt_best_tradeoff() {
    let rows = ex::fig10::run();
    let ours = rows.iter().find(|r| r.method == "CrowdHMTware").unwrap();
    let ada = rows.iter().find(|r| r.method == "AdaDeep").unwrap();
    let orig = rows.iter().find(|r| r.method == "Original").unwrap();
    assert!(ours.accuracy >= ada.accuracy, "ours {:.2} vs ada {:.2}", ours.accuracy, ada.accuracy);
    assert!(ours.latency_s <= ada.latency_s * 1.05);
    assert!(ours.energy_j < orig.energy_j * 0.5);
    // All baselines compress vs original.
    for r in &rows {
        if r.method != "Original" {
            assert!(r.params_m < orig.params_m, "{}", r.method);
        }
    }
}

#[test]
fn table3_operator_combos_win_efficiency_within_accuracy_band() {
    let rows = ex::table3::run();
    assert_eq!(rows.len(), 5);
    for r in &rows {
        // The ImageNet-sized backbone is architecture-limited (its stem
        // keeps 112² activations, unlike MobileNet's stride pyramid), so
        // its MAC gain is modest; every other task clears 1.5×.
        let floor = if r.dataset == "ImageNet" { 1.2 } else { 1.5 };
        assert!(r.macs_gain > floor, "{} on {}: MACs {:.1}", r.combo, r.dataset, r.macs_gain);
        assert!(r.energy_gain > 1.0, "{} on {}: energy {:.1}", r.combo, r.dataset, r.energy_gain);
        assert!(r.acc_delta.abs() < 6.0, "{} on {}: Δacc {:.1}", r.combo, r.dataset, r.acc_delta);
    }
}

#[test]
fn fig11_crowdhmt_beats_cas_and_dads() {
    let rows = ex::fig11::run();
    let ours = rows.iter().find(|r| r.method == "CrowdHMTware").unwrap();
    for base in ["CAS", "DADS"] {
        let b = rows.iter().find(|r| r.method == base).unwrap();
        assert!(
            ours.latency_s <= b.latency_s + 1e-9,
            "{}: ours {:.3} vs {:.3}",
            base,
            ours.latency_s,
            b.latency_s
        );
    }
}

#[test]
fn table4_cross_level_dominates_single_level() {
    let rows = ex::table4::run();
    let by = |m: &str| rows.iter().find(|r| r.method == m).unwrap();
    let orig = by("ResNet-18");
    let fusion = by("Operator fusion");
    let par = by("Operator parallelism");
    let full = by("Parallelism+Pruning+Fusion+MemAlloc");
    // Paper's directions: every mechanism cuts latency; the full
    // cross-level combination cuts the most (−48.4% in the paper).
    assert!(fusion.latency_ms < orig.latency_ms);
    assert!(par.latency_ms < orig.latency_ms);
    assert!(full.speedup_pct > 40.0, "full speedup {:.1}%", full.speedup_pct);
    for r in &rows {
        assert!(full.latency_ms <= r.latency_ms + 1e-9, "full must be fastest vs {}", r.method);
    }
    // Backend-only paths keep accuracy exactly.
    assert_eq!(fusion.accuracy, orig.accuracy);
    assert_eq!(par.accuracy, orig.accuracy);
}

#[test]
fn table5_full_system_fastest() {
    let rows = ex::table5::run();
    assert_eq!(rows.len(), 4);
    let full = rows.last().unwrap();
    assert!(full.method.contains("all three"));
    for r in &rows[..3] {
        assert!(
            full.latency_s <= r.latency_s + 1e-9,
            "full {:.3}s vs {} {:.3}s",
            full.latency_s,
            r.method,
            r.latency_s
        );
    }
    // Compression pairs cut params; engine cuts memory.
    let comp_eng = &rows[1];
    assert!(comp_eng.params_m < 5.0);
}

#[test]
fn fig13_strategy_switches_follow_the_day() {
    let log = ex::fig13::run(6);
    assert_eq!(log.len(), 30);
    // At least two distinct strategies across the day.
    let mut strategies: Vec<&str> = log.iter().map(|e| e.chosen.as_str()).collect();
    strategies.dedup();
    assert!(strategies.len() >= 2, "no adaptation happened: {strategies:?}");
    // The battery trace is the paper's 90% → 21%.
    assert!((log.first().unwrap().battery - 0.9).abs() < 1e-9);
    assert!((log.last().unwrap().battery - 0.21).abs() < 1e-9);
    // Memory crunch phase (ticks 13..18) must not exceed its budget by
    // running the biggest on-device config: the loop offloads or shrinks.
    let crunch: Vec<_> = log.iter().filter(|e| e.tick > 12 && e.tick <= 18).collect();
    assert!(
        crunch.iter().any(|e| e.offloaded) || crunch.iter().all(|e| e.memory_mb <= e.mem_budget_mb),
        "memory crunch unhandled"
    );
}
