//! Loom model: the frontier-coalescing window's seed/tune protocol
//! ([`crowdhmtware::coordinator::FrontierWindow`]).
//!
//! Checked invariants:
//!
//! - **Seed publication**: `seed` stores the window values and *then*
//!   Release-publishes the seeded flag, so any thread that
//!   Acquire-observes `seeded()` reads the seeded values — never the
//!   pre-seed defaults. This is the ordering `maintain()`'s retune
//!   depends on (it tunes from `seed_batch()` after checking
//!   `seeded()`).
//! - **Retune vs link-thread close**: the link thread deciding a
//!   window's close trigger (`batch()` / `config()`) concurrently with
//!   a `maintain` retune (`set_batch` / `set`) observes a value from
//!   one of the two epochs — never garbage, never a batch below 1.
//!
//! The `mutant_*` test re-seeds the flag-before-values bug and
//! demonstrates loom catches the schedule where an observer sees the
//! flag but reads the defaults.
//!
//! Runs only under `RUSTFLAGS="--cfg loom"` (the `loom` CI job).
#![cfg(loom)]

use std::time::Duration;

use crowdhmtware::coordinator::FrontierWindow;
use crowdhmtware::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use crowdhmtware::sync::{thread, Arc};

/// Bounded exploration; see `loom_steal.rs` for the rationale.
fn model<F: Fn() + Sync + Send + 'static>(f: F) {
    let mut b = loom::model::Builder::new();
    b.preemption_bound = Some(3);
    b.check(f);
}

/// A `maintain` tick Acquire-observing the seeded flag reads the seeded
/// window, never the `off()` defaults — the one-shot publication the
/// Release store in `seed` guarantees.
#[test]
fn observing_the_seeded_flag_implies_the_seeded_values() {
    model(|| {
        let w = Arc::new(FrontierWindow::off());
        let w1 = Arc::clone(&w);
        let seeder = thread::spawn(move || {
            w1.seed(4, Duration::from_micros(250));
        });
        let w2 = Arc::clone(&w);
        let maintainer = thread::spawn(move || {
            if w2.seeded() {
                assert_eq!(w2.seed_batch(), 4, "seeded flag up, seed value missing");
                assert_eq!(w2.batch(), 4, "seeded flag up, window still at defaults");
                assert_eq!(
                    w2.config().max_wait,
                    Duration::from_micros(250),
                    "seeded flag up, wait still at defaults"
                );
            }
        });
        seeder.join().unwrap();
        maintainer.join().unwrap();
        assert!(w.seeded());
        assert_eq!(w.seed_batch(), 4);
    });
}

/// The link thread reads its close trigger while `maintain` retunes the
/// window: every observation is from one of the two epochs (the
/// advisory-scalar contract), the floor of 1 always holds, and after
/// both settle the retuned values win.
#[test]
fn retune_racing_the_link_thread_yields_only_epoch_values() {
    model(|| {
        let w = Arc::new(FrontierWindow::off());
        w.seed(2, Duration::from_micros(100));
        let w1 = Arc::clone(&w);
        let maintainer = thread::spawn(move || {
            // `maintain`'s retune path: tune only a seeded window.
            if w1.seeded() && w1.seed_batch() > 1 {
                w1.set(4, Duration::from_micros(200));
            }
        });
        let w2 = Arc::clone(&w);
        let link = thread::spawn(move || {
            // The link thread's wakeup read: fullness + age triggers.
            let cfg = w2.config();
            (w2.batch(), cfg.max_wait)
        });
        maintainer.join().unwrap();
        let (batch, wait) = link.join().unwrap();
        assert!(batch == 2 || batch == 4, "batch outside both epochs: {batch}");
        assert!(
            wait == Duration::from_micros(100) || wait == Duration::from_micros(200),
            "wait outside both epochs: {wait:?}"
        );
        assert_eq!(w.batch(), 4, "the retune must stick once settled");
        assert_eq!(w.seed_batch(), 2, "retunes never rewrite what the seed picked");
    });
}

/// Seeded mutant — the flag-before-values bug `FrontierWindow::seed`'s
/// store order fixes: publishing the seeded flag *before* the window
/// values lets an observer pass the `seeded()` gate and still read the
/// pre-seed defaults. Loom finds the schedule; the test passes only
/// because the model panics.
#[test]
#[should_panic]
fn mutant_flag_published_before_values_leaks_the_defaults() {
    model(|| {
        let batch = Arc::new(AtomicUsize::new(1));
        let seeded = Arc::new(AtomicBool::new(false));
        let b1 = Arc::clone(&batch);
        let s1 = Arc::clone(&seeded);
        let seeder = thread::spawn(move || {
            // The mutant: flag first, values after.
            s1.store(true, Ordering::Release);
            b1.store(4, Ordering::Relaxed);
        });
        let b2 = Arc::clone(&batch);
        let s2 = Arc::clone(&seeded);
        let observer = thread::spawn(move || {
            if s2.load(Ordering::Acquire) {
                assert_eq!(b2.load(Ordering::Relaxed), 4, "seeded flag up, defaults visible");
            }
        });
        seeder.join().unwrap();
        observer.join().unwrap();
    });
}
