//! Loom model: atomic variant switching through
//! [`crowdhmtware::coordinator::SwitchGate`].
//!
//! Checked invariants:
//!
//! - **Unique, ordered generations**: concurrent `begin` calls hand out
//!   distinct, strictly-increasing generation numbers.
//! - **Consistent reads**: `current()` never returns a torn
//!   (variant, generation) pair — every observation matches some switch
//!   that actually happened.
//! - **Filtered acks** (the PR 4 fix): a worker absorbing racing switch
//!   broadcasts through [`SwitchGate::accepts`] can never end on an
//!   older generation than the last acknowledged switch — stale
//!   messages are filtered, not applied.
//!
//! The `mutant_*` test re-seeds the pre-fix bug (absorbing every
//! broadcast unfiltered) and demonstrates loom catches the interleaving
//! where the older broadcast lands last.
//!
//! Runs only under `RUSTFLAGS="--cfg loom"` (the `loom` CI job).
#![cfg(loom)]

use crowdhmtware::coordinator::SwitchGate;
use crowdhmtware::sync::{lock_or_recover, thread, Arc, Mutex};

/// Bounded exploration; see `loom_steal.rs` for the rationale.
fn model<F: Fn() + Sync + Send + 'static>(f: F) {
    let mut b = loom::model::Builder::new();
    b.preemption_bound = Some(3);
    b.check(f);
}

/// A worker's absorb loop: drain `want` broadcasts from the shared
/// inbox, applying each through the gate's ack filter (the exact
/// predicate `WorkerState::absorb` and the pool's ack waiter use).
fn absorb_loop(inbox: &Mutex<Vec<u64>>, want: usize, filtered: bool) -> u64 {
    let mut local = 0u64;
    let mut absorbed = 0;
    while absorbed < want {
        let msg = lock_or_recover(inbox).pop();
        match msg {
            Some(g) => {
                if !filtered || SwitchGate::accepts(g, local) {
                    local = g;
                }
                absorbed += 1;
            }
            None => loom::thread::yield_now(),
        }
    }
    local
}

/// Two racing switches: generations are unique, and a worker draining
/// both broadcasts (in whatever order the race delivered them) always
/// ends on the *newest* generation — the fixed ack filter never lets a
/// stale broadcast regress it.
#[test]
fn racing_switches_leave_the_worker_on_the_newest_generation() {
    model(|| {
        let gate = Arc::new(SwitchGate::new("base"));
        let inbox = Arc::new(Mutex::new(Vec::new()));

        let mut switchers = Vec::new();
        for variant in ["a", "b"] {
            let gate = Arc::clone(&gate);
            let inbox = Arc::clone(&inbox);
            switchers.push(thread::spawn(move || {
                // `switch_variant_acked`'s sequence: bump the gate, then
                // broadcast the required generation to the workers.
                let g = gate.begin(variant);
                lock_or_recover(&inbox).push(g);
                g
            }));
        }
        let i2 = Arc::clone(&inbox);
        let worker = thread::spawn(move || absorb_loop(&i2, 2, true));

        let g1 = switchers.remove(0).join().unwrap();
        let g2 = switchers.remove(0).join().unwrap();
        let local = worker.join().unwrap();

        let mut gens = [g1, g2];
        gens.sort_unstable();
        assert_eq!(gens, [1, 2], "concurrent begins must hand out distinct generations");
        assert_eq!(local, 2, "a stale broadcast regressed the worker's generation");
        assert_eq!(gate.generation(), 2);
    });
}

/// `current()` is a single consistent read: concurrent with one switch,
/// an observer sees either the pre-switch pair or the post-switch pair
/// — never the new variant with the old generation or vice versa.
#[test]
fn current_never_returns_a_torn_pair() {
    model(|| {
        let gate = Arc::new(SwitchGate::new("base"));
        let g1 = Arc::clone(&gate);
        let switcher = thread::spawn(move || g1.begin("upgraded"));
        let g2 = Arc::clone(&gate);
        let observer = thread::spawn(move || {
            let (v, g) = g2.current();
            (v.to_string(), g)
        });
        let new_gen = switcher.join().unwrap();
        let (v, g) = observer.join().unwrap();
        assert_eq!(new_gen, 1);
        assert!(
            (v == "base" && g == 0) || (v == "upgraded" && g == 1),
            "torn read: ({v:?}, {g})"
        );
    });
}

/// Seeded mutant — the pre-fix absorb: applying every broadcast without
/// the `accepts` generation filter lets the interleaving where the
/// older switch's message is delivered *after* the newer one leave the
/// worker serving the stale variant (while both switch calls report
/// success). Loom finds it; the test passes only because the model
/// panics.
#[test]
#[should_panic]
fn mutant_unfiltered_absorb_regresses_to_a_stale_switch() {
    model(|| {
        let gate = Arc::new(SwitchGate::new("base"));
        let inbox = Arc::new(Mutex::new(Vec::new()));

        let mut switchers = Vec::new();
        for variant in ["a", "b"] {
            let gate = Arc::clone(&gate);
            let inbox = Arc::clone(&inbox);
            switchers.push(thread::spawn(move || {
                let g = gate.begin(variant);
                lock_or_recover(&inbox).push(g);
                g
            }));
        }
        let i2 = Arc::clone(&inbox);
        let worker = thread::spawn(move || absorb_loop(&i2, 2, false));

        for s in switchers {
            s.join().unwrap();
        }
        let local = worker.join().unwrap();
        assert_eq!(local, 2, "a stale broadcast regressed the worker's generation");
    });
}
