//! Loom model: the work-stealing lane protocol
//! ([`crowdhmtware::coordinator::StealDeque`] +
//! [`crowdhmtware::coordinator::StealRegistry`]).
//!
//! Checked invariant — **every admitted request leaves the lane exactly
//! once**: whatever interleaving of the owner's `pop_front`, a thief's
//! `steal_tail`, and the pool's `drain_dead` reclaim, no request is
//! served twice and none is lost, and the depth gauge/failed counter
//! stay truthful.
//!
//! The `mutant_*` test re-seeds the bug the one-lock discipline fixes
//! (a two-step peek-then-pop claim) and demonstrates loom catches it:
//! it MUST fail, and is kept as `#[should_panic]` proof that the model
//! has teeth.
//!
//! Runs only under `RUSTFLAGS="--cfg loom"` (the `loom` CI job).
#![cfg(loom)]

use std::collections::VecDeque;
use std::time::Instant;

use crowdhmtware::coordinator::{Lane, Request, StealDeque, StealRegistry};
use crowdhmtware::sync::{lock_or_recover, mpsc::channel, thread, Arc, Mutex};
use crowdhmtware::telemetry::TelemetryHub;

/// Bounded exploration: the protocols here are a handful of lock
/// acquisitions, so 3 preemptions reach every distinguishable
/// interleaving while keeping the job seconds-fast.
fn model<F: Fn() + Sync + Send + 'static>(f: F) {
    let mut b = loom::model::Builder::new();
    b.preemption_bound = Some(3);
    b.check(f);
}

fn req(id: u64) -> Request {
    let (resp, _rx) = channel();
    Request {
        id,
        input: vec![0.0f32; 1].into(),
        enqueued: Instant::now(),
        lane: Lane::Normal,
        resp,
        cache: None,
    }
}

/// Owner pops the front while a thief splits off the tail: the union of
/// popped + stolen + remaining is exactly the admitted set, no
/// duplicates, no losses.
#[test]
fn owner_pop_vs_thief_steal_neither_duplicates_nor_drops() {
    model(|| {
        let d = Arc::new(StealDeque::new());
        for i in 0..3 {
            d.push_back(req(i));
        }
        let d1 = Arc::clone(&d);
        let owner = thread::spawn(move || {
            let mut got = Vec::new();
            for _ in 0..2 {
                if let Some(r) = d1.pop_front() {
                    got.push(r.id);
                }
            }
            got
        });
        let d2 = Arc::clone(&d);
        let thief = thread::spawn(move || {
            d2.steal_tail(2).into_iter().map(|r| r.id).collect::<Vec<u64>>()
        });
        let mut all = owner.join().unwrap();
        all.extend(thief.join().unwrap());
        while let Some(r) = d.pop_front() {
            all.push(r.id);
        }
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2], "a request was double-served or lost");
    });
}

/// The pool reclaiming a dead worker's lane (`drain_dead`) races a
/// thief still stealing from it: each stranded request is either failed
/// by the reclaim or migrated by the thief — never both, never neither
/// — and the telemetry gauge/counter agree with where they went.
#[test]
fn drain_dead_vs_thief_partition_the_lane() {
    model(|| {
        let hub = Arc::new(TelemetryHub::new(4));
        let reg = Arc::new(StealRegistry::new());
        let tel = hub.register(0);
        let d = Arc::new(StealDeque::new());
        reg.register(0, Arc::clone(&d), Arc::clone(&tel));
        for i in 0..2 {
            d.push_back(req(i));
            tel.depth_add(1);
        }
        let r1 = Arc::clone(&reg);
        let pool = thread::spawn(move || r1.drain_dead(0));
        let d2 = Arc::clone(&d);
        let t2 = Arc::clone(&tel);
        let thief = thread::spawn(move || {
            // The thief moves the admission accounting with the work,
            // exactly as the pool's steal phase does.
            let stolen = d2.steal_tail(1);
            t2.depth_sub(stolen.len());
            stolen.len()
        });
        let drained = pool.join().unwrap();
        let stolen = thief.join().unwrap();
        assert_eq!(drained + stolen + d.len(), 2, "requests double-claimed or lost");
        assert_eq!(tel.queue_depth(), d.len(), "depth gauge out of step with the lane");
        assert_eq!(tel.failed(), drained, "every drained request is a counted failure");
    });
}

/// Seeded mutant — the bug `StealDeque::pop_front`'s single-lock claim
/// prevents: peeking the front and re-locking to remove it lets a thief
/// drain the lane in between, so the owner serves a request the thief
/// also took. Loom finds the interleaving; the test passes only because
/// the model panics.
#[test]
#[should_panic]
fn mutant_two_step_pop_double_serves_under_a_racing_thief() {
    model(|| {
        let q = Arc::new(Mutex::new(VecDeque::from([0u64, 1])));
        let q1 = Arc::clone(&q);
        let owner = thread::spawn(move || {
            // The mutant: claim = unlocked peek + separate pop.
            let peeked = lock_or_recover(&q1).front().copied();
            let _ = lock_or_recover(&q1).pop_front();
            peeked
        });
        let q2 = Arc::clone(&q);
        let thief = thread::spawn(move || {
            lock_or_recover(&q2).drain(..).collect::<Vec<u64>>()
        });
        let mut all: Vec<u64> = owner.join().unwrap().into_iter().collect();
        all.extend(thief.join().unwrap());
        all.extend(lock_or_recover(&q).iter().copied());
        all.sort_unstable();
        assert_eq!(all, vec![0, 1], "the two-step pop double-claimed a request");
    });
}
