//! Loom model: the tenancy arm's lock-free admission gates
//! ([`crowdhmtware::coordinator::TokenBucket`] /
//! [`crowdhmtware::coordinator::Bulkhead`] /
//! [`crowdhmtware::coordinator::TenantPermit`]).
//!
//! Checked invariants (the **Tenant budgets** bullet in
//! `coordinator/mod.rs`):
//!
//! - **Exactly-one token**: a bucket holding one token admits exactly
//!   one of two racing takers — the level CAS hands each token to one
//!   caller, never both, never neither.
//! - **Refill credits once**: two takers racing the lazy refill on the
//!   same clock reading credit the elapsed interval at most once (the
//!   timestamp CAS arbitrates; the loser re-reads instead of
//!   double-crediting), so a 1-token interval admits at most one.
//! - **Bulkhead cap**: `held` never exceeds `cap` under concurrent
//!   acquire/release, and every [`TenantPermit`] drop releases the
//!   slot it holds exactly once (drop racing a fresh acquire).
//!
//! The `mutant_*` test re-seeds the classic load-check-then-`fetch_add`
//! TOCTOU the bulkhead's check-then-CAS loop exists to prevent, and
//! passes only because loom finds the over-cap schedule.
//!
//! Runs only under `RUSTFLAGS="--cfg loom"` (the `loom` CI job).
#![cfg(loom)]

use crowdhmtware::coordinator::{Bulkhead, TenantPermit, TokenBucket};
use crowdhmtware::sync::atomic::{AtomicUsize, Ordering};
use crowdhmtware::sync::{thread, Arc};

/// Bounded exploration; see `loom_steal.rs` for the rationale.
fn model<F: Fn() + Sync + Send + 'static>(f: F) {
    let mut b = loom::model::Builder::new();
    b.preemption_bound = Some(3);
    b.check(f);
}

/// Two takers race a bucket holding exactly one token: exactly one is
/// admitted on every schedule.
#[test]
fn one_token_admits_exactly_one_of_two_racing_takers() {
    model(|| {
        let bucket = Arc::new(TokenBucket::new(0.0, 8));
        // Drain the cold burst, then grant exactly one token back.
        while bucket.try_take(0) {}
        bucket.grant(1);
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let b = Arc::clone(&bucket);
                thread::spawn(move || b.try_take(0))
            })
            .collect();
        let admitted = handles.into_iter().map(|h| h.join().unwrap()).filter(|&ok| ok).count();
        assert_eq!(admitted, 1, "one token must admit exactly one taker");
        assert_eq!(bucket.level_tokens(), 0);
    });
}

/// Two takers race the lazy refill itself on the same clock reading: a
/// 1-token elapsed interval is credited once, so at most one taker is
/// admitted — a losing refiller re-reads rather than double-credits.
#[test]
fn racing_refillers_credit_the_interval_once() {
    model(|| {
        // 1 token/s, empty bucket, both takers observe t = 1 s: the
        // interval is worth exactly one token.
        let bucket = Arc::new(TokenBucket::new(1.0, 4));
        while bucket.try_take(0) {}
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let b = Arc::clone(&bucket);
                thread::spawn(move || b.try_take(1_000_000))
            })
            .collect();
        let admitted = handles.into_iter().map(|h| h.join().unwrap()).filter(|&ok| ok).count();
        assert!(admitted <= 1, "interval credited twice: {admitted} admitted");
    });
}

/// A cap-1 bulkhead under a concurrent permit drop and a fresh
/// acquire: `held` never exceeds the cap, the drop releases exactly
/// once, and after both settle the slot count matches the survivors.
#[test]
fn bulkhead_cap_holds_under_release_acquire_race() {
    model(|| {
        let bh = Arc::new(Bulkhead::new(1));
        assert!(bh.try_acquire());
        let holder = TenantPermit::new(None, Some(Arc::clone(&bh)));
        let b1 = Arc::clone(&bh);
        let dropper = thread::spawn(move || drop(holder));
        let b2 = Arc::clone(&bh);
        let acquirer = thread::spawn(move || {
            let got = b2.try_acquire();
            assert!(b2.held() <= 1, "cap exceeded: {} held", b2.held());
            got
        });
        dropper.join().unwrap();
        let got = acquirer.join().unwrap();
        // After the drop settled: either the acquirer won the freed
        // slot (held 1) or lost the race to it (held 0).
        assert_eq!(bh.held(), usize::from(got));
        assert!(bh.held() <= 1);
    });
}

/// Seeded mutant — the load-check-then-`fetch_add` TOCTOU
/// `Bulkhead::try_acquire`'s CAS loop prevents: two admitters both
/// pass the non-atomic check, both increment, and a cap-1 bulkhead
/// holds 2. Loom finds the schedule; the test passes only because the
/// model panics.
#[test]
#[should_panic]
fn mutant_check_then_fetch_add_overshoots_the_cap() {
    model(|| {
        let held = Arc::new(AtomicUsize::new(0));
        let cap = 1usize;
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let h = Arc::clone(&held);
                thread::spawn(move || {
                    // The mutant: check, then increment non-atomically.
                    if h.load(Ordering::Relaxed) < cap {
                        h.fetch_add(1, Ordering::Relaxed);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert!(held.load(Ordering::Relaxed) <= cap, "bulkhead cap exceeded");
    });
}
