//! Loom model: the single-flight response cache
//! ([`crowdhmtware::coordinator::ResponseCache`]).
//!
//! Checked invariants:
//!
//! - **Single flight, no stranded waiter**: of N identical concurrent
//!   submissions exactly one leads; once the leader completes, every
//!   waiter holds the leader's response (fan-out happens before the
//!   flight entry is released).
//! - **Leader death wakes waiters**: a leader dropped un-completed
//!   closes every waiter's channel (they observe the failure, they
//!   don't hang) and frees the key for a fresh flight.
//! - **Generation bump never serves stale**: a lookup carrying the
//!   post-switch generation can never hit an entry cached under the old
//!   one, whatever the interleaving of the switch and an in-flight
//!   leader.
//!
//! The `mutant_*` test re-seeds the bug `CacheSlot`'s `Drop` cleanup
//! fixes (a dying leader leaving its in-flight entry — and the waiters'
//! senders — in the map) and demonstrates loom catches it.
//!
//! Runs only under `RUSTFLAGS="--cfg loom"` (the `loom` CI job).
#![cfg(loom)]

use std::collections::HashMap;
use std::time::Duration;

use crowdhmtware::coordinator::{CacheOutcome, Lane, Response, ResponseCache, SwitchGate};
use crowdhmtware::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use crowdhmtware::sync::{lock_or_recover, thread, Arc, Mutex};
use crowdhmtware::telemetry::TelemetryHub;

/// Bounded exploration; see `loom_steal.rs` for the rationale.
fn model<F: Fn() + Sync + Send + 'static>(f: F) {
    let mut b = loom::model::Builder::new();
    b.preemption_bound = Some(3);
    b.check(f);
}

fn resp(pred: usize, generation: u64) -> Response {
    Response {
        id: 0,
        pred,
        confidence: 1.0,
        variant: Arc::from("v"),
        generation,
        worker: 0,
        lane: Lane::Normal,
        latency: Duration::from_millis(1),
    }
}

fn cache() -> (Arc<TelemetryHub>, Arc<ResponseCache>) {
    let hub = Arc::new(TelemetryHub::new(4));
    let c = Arc::new(ResponseCache::new(4, Arc::clone(&hub)));
    (hub, c)
}

/// Two identical concurrent submissions: one inference, two answers.
/// Whichever thread leads completes; the other (hit or joined waiter)
/// must find the leader's response already fanned out by the time the
/// leader thread finished.
#[test]
fn leader_completes_before_any_waiter_can_miss_the_send() {
    model(|| {
        let (_hub, c) = cache();
        let v: Arc<str> = Arc::from("v");
        let input: Arc<[f32]> = vec![1.0f32].into();
        let mut joins = Vec::new();
        for _ in 0..2 {
            let c = Arc::clone(&c);
            let v = Arc::clone(&v);
            let input = Arc::clone(&input);
            joins.push(thread::spawn(move || {
                match c.lookup(&input, &v, 0, true) {
                    CacheOutcome::Lead(slot) => {
                        // The leader "runs the inference" and completes.
                        slot.complete(&resp(3, 0));
                        Ok(3)
                    }
                    CacheOutcome::Hit(rx) | CacheOutcome::Joined(rx) => Err(rx),
                    CacheOutcome::Bypass => panic!("no collision is possible here"),
                }
            }));
        }
        let mut preds = Vec::new();
        for j in joins {
            match j.join().unwrap() {
                Ok(p) => preds.push(p),
                // The joins above ordered the leader's complete before
                // this drain: an Empty channel here is a lost waiter.
                Err(rx) => preds.push(rx.try_recv().expect("waiter stranded by the flight").pred),
            }
        }
        assert_eq!(preds, vec![3, 3], "every submission gets the leader's answer");
        assert_eq!(c.inflight_len(), 0, "the flight entry must be released");
        assert_eq!(c.completed_len(), 1, "one inference, one cached entry");
    });
}

/// A leader dropped un-completed (executor failure, worker death): its
/// waiters' channels close — same failure the leader's caller sees —
/// and the key immediately admits a fresh flight.
#[test]
fn dead_leader_wakes_waiters_and_frees_the_key() {
    model(|| {
        let (_hub, c) = cache();
        let v: Arc<str> = Arc::from("v");
        let input: Arc<[f32]> = vec![9.0f32].into();
        let mut joins = Vec::new();
        for _ in 0..2 {
            let c = Arc::clone(&c);
            let v = Arc::clone(&v);
            let input = Arc::clone(&input);
            joins.push(thread::spawn(move || {
                match c.lookup(&input, &v, 0, true) {
                    // Every leader dies un-completed in this model.
                    CacheOutcome::Lead(slot) => {
                        drop(slot);
                        None
                    }
                    CacheOutcome::Joined(rx) => Some(rx),
                    CacheOutcome::Hit(_) => panic!("nothing ever completes"),
                    CacheOutcome::Bypass => panic!("no collision is possible here"),
                }
            }));
        }
        let waiters: Vec<Receiver<Response>> =
            joins.into_iter().filter_map(|j| j.join().unwrap()).collect();
        for rx in waiters {
            assert!(
                matches!(rx.try_recv(), Err(TryRecvError::Disconnected)),
                "a dead leader's waiter must observe the failure, not hang"
            );
        }
        assert_eq!(c.inflight_len(), 0, "the dead flight must be cleared");
        assert!(
            matches!(c.lookup(&input, &v, 0, true), CacheOutcome::Lead(_)),
            "the key must be retryable after the leader's death"
        );
    });
}

/// An admission snapshotting `(variant, generation)` from the gate races
/// `switch_variant`'s begin + purge: whatever the interleaving, a
/// post-switch lookup can only hit an entry completed under the new
/// generation — never a stale pre-switch answer.
#[test]
fn generation_bump_never_serves_a_stale_answer() {
    model(|| {
        let (_hub, c) = cache();
        let gate = Arc::new(SwitchGate::new("base"));
        let input: Arc<[f32]> = vec![2.0f32].into();

        let c1 = Arc::clone(&c);
        let g1 = Arc::clone(&gate);
        let i1 = Arc::clone(&input);
        let requester = thread::spawn(move || {
            // Admission order: one consistent (variant, generation) read,
            // then the cache consult — exactly `submit_lane`'s sequence.
            let (v, g) = g1.current();
            if let CacheOutcome::Lead(slot) = c1.lookup(&i1, &v, g, true) {
                slot.complete(&resp(1, g));
            }
        });
        let c2 = Arc::clone(&c);
        let g2 = Arc::clone(&gate);
        let switcher = thread::spawn(move || {
            // `switch_variant`'s sequence: bump the gate, then purge.
            let g = g2.begin("upgraded");
            c2.purge_stale(g);
            g
        });
        requester.join().unwrap();
        let g_new = switcher.join().unwrap();

        // A post-switch admission (both racers joined: the gate now
        // reads the new variant) must never see a pre-switch response.
        let (v, g) = gate.current();
        assert_eq!(g, g_new);
        match c.lookup(&input, &v, g, true) {
            CacheOutcome::Hit(rx) => {
                let r = rx.try_recv().expect("hit carries its response");
                assert_eq!(r.generation, g_new, "stale answer served across a switch");
            }
            CacheOutcome::Lead(slot) => drop(slot),
            CacheOutcome::Joined(_) | CacheOutcome::Bypass => {
                panic!("no flight or collision can be live here")
            }
        }
    });
}

/// Seeded mutant — the bug `CacheSlot::drop` fixes: a dying leader that
/// does *not* clear its in-flight entry leaves the waiters' senders
/// alive inside the map, so the waiters' channels never close and their
/// callers hang. Loom finds the lead→join→death interleaving; the test
/// passes only because the model panics.
#[test]
#[should_panic]
fn mutant_leader_death_without_cleanup_strands_waiters() {
    model(|| {
        // In-flight map replica with the Drop cleanup removed.
        type Flights = Arc<Mutex<HashMap<u64, Vec<Sender<u64>>>>>;
        let flights: Flights = Arc::new(Mutex::new(HashMap::new()));

        let f1 = Arc::clone(&flights);
        let leader = thread::spawn(move || {
            lock_or_recover(&f1).insert(7, Vec::new());
            // Leader dies here. The mutant: no cleanup — the entry (and
            // any waiter senders pushed meanwhile) stay in the map.
        });
        let f2 = Arc::clone(&flights);
        let waiter = thread::spawn(move || {
            let mut m = lock_or_recover(&f2);
            m.get_mut(&7).map(|ws| {
                let (tx, rx) = channel();
                ws.push(tx);
                rx
            })
        });
        leader.join().unwrap();
        if let Some(rx) = waiter.join().unwrap() {
            assert!(
                matches!(rx.try_recv(), Err(TryRecvError::Disconnected)),
                "waiter stranded: the leader died but its flight entry survived"
            );
        }
    });
}
