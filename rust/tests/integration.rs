//! Cross-module integration and property-based tests (hand-rolled
//! generators over the seeded RNG — no proptest crate in this offline
//! build). Each property runs across many random graphs/configurations.

use crowdhmtware::compress::{self, OperatorKind, VariantSpec};
use crowdhmtware::device::{all_devices, ContextState, DynamicsSim, ResourceMonitor};
use crowdhmtware::engine::{allocate, fuse, lifetimes, FusionConfig};
use crowdhmtware::graph::{Activation, Conv2dAttrs, CostProfile, Graph, Op, PoolKind, Shape};
use crowdhmtware::models::{backbone, BackboneConfig};
use crowdhmtware::partition::{plan_offload, prepartition, DeviceState, Topology};
use crowdhmtware::profiler::{estimate_energy, estimate_latency};
use crowdhmtware::transform::{from_json, optimize, to_json};
use crowdhmtware::util::Rng;

/// Random CNN-ish chain graph with occasional residual blocks.
fn random_graph(rng: &mut Rng) -> Graph {
    let c0 = [1usize, 3][rng.gen_index(2)];
    let hw = [16usize, 32, 24][rng.gen_index(3)];
    let mut g = Graph::new("rand", Shape::nchw(1, c0, hw, hw));
    let mut x = g.input;
    let mut width = [8usize, 16][rng.gen_index(2)];
    let depth = 3 + rng.gen_index(6);
    for i in 0..depth {
        match rng.gen_index(5) {
            0 | 1 => {
                // conv-bn-relu
                let c = g.add(format!("c{i}"), Op::Conv2d(Conv2dAttrs::simple(width, 3, 1, 1)), &[x]);
                let b = g.add(format!("b{i}"), Op::BatchNorm, &[c]);
                x = g.add(format!("r{i}"), Op::Act(Activation::ReLU), &[b]);
            }
            2 => {
                // residual block (identity shortcut)
                let in_c = g.node(x).shape.channels();
                let c1 = g.add(format!("rb{i}.a"), Op::Conv2d(Conv2dAttrs::simple(in_c, 3, 1, 1)), &[x]);
                let r1 = g.add(format!("rb{i}.ar"), Op::Act(Activation::ReLU), &[c1]);
                let c2 = g.add(format!("rb{i}.b"), Op::Conv2d(Conv2dAttrs::simple(in_c, 3, 1, 1)), &[r1]);
                let add = g.add(format!("rb{i}.add"), Op::Add, &[c2, x]);
                x = g.add(format!("rb{i}.relu"), Op::Act(Activation::ReLU), &[add]);
            }
            3 => {
                let (h, _) = g.node(x).shape.hw();
                if h >= 4 {
                    x = g.add(format!("p{i}"), Op::Pool { kind: PoolKind::Max, kernel: 2, stride: 2 }, &[x]);
                }
            }
            _ => {
                width = (width * 2).min(64);
                let c = g.add(format!("w{i}"), Op::Conv2d(Conv2dAttrs::simple(width, 3, 1, 1)), &[x]);
                x = g.add(format!("wr{i}"), Op::Act(Activation::ReLU), &[c]);
            }
        }
    }
    let gap = g.add("gap", Op::GlobalAvgPool, &[x]);
    let fl = g.add("flat", Op::Flatten, &[gap]);
    let fc = g.add("fc", Op::FC { out: 10, bias: true }, &[fl]);
    let sm = g.add("sm", Op::Softmax, &[fc]);
    g.mark_output(sm);
    g
}

#[test]
fn prop_fusion_never_changes_output_shape_or_grows_cost() {
    let mut rng = Rng::seed_from_u64(11);
    for _ in 0..40 {
        let g = random_graph(&mut rng);
        let (f, _) = fuse(&g, FusionConfig::all());
        assert_eq!(f.node(f.outputs[0]).shape, g.node(g.outputs[0]).shape);
        assert!(f.len() <= g.len());
        assert!(f.total_macs() <= g.total_macs());
        assert!(CostProfile::of(&f).total_mem_bytes() <= CostProfile::of(&g).total_mem_bytes());
        assert_eq!(f.topo_order().len(), f.len());
    }
}

#[test]
fn prop_compression_operators_shrink_and_preserve_classifier() {
    let mut rng = Rng::seed_from_u64(13);
    for _ in 0..25 {
        let g = random_graph(&mut rng);
        for k in OperatorKind::all() {
            let level = [0.25, 0.5, 0.75][rng.gen_index(3)];
            let v = compress::apply(&g, k, level);
            assert!(v.total_macs() <= g.total_macs(), "{k:?}@{level} grew");
            assert_eq!(v.node(v.outputs[0]).shape.features(), 10, "{k:?} classifier");
            assert_eq!(v.topo_order().len(), v.len(), "{k:?} cycle");
        }
    }
}

#[test]
fn prop_exchange_roundtrip_exact() {
    let mut rng = Rng::seed_from_u64(17);
    for _ in 0..25 {
        let g = random_graph(&mut rng);
        let g2 = from_json(&to_json(&g)).expect("roundtrip");
        assert_eq!(g2.len(), g.len());
        assert_eq!(g2.total_macs(), g.total_macs());
        assert_eq!(g2.total_params(), g.total_params());
        // And through the redundancy optimizer: cost never grows.
        let (o, _) = optimize(&g2);
        assert!(o.total_macs() <= g2.total_macs());
    }
}

#[test]
fn prop_memalloc_correct_on_random_graphs() {
    let mut rng = Rng::seed_from_u64(19);
    for _ in 0..30 {
        let g = random_graph(&mut rng);
        let plan = allocate(&g);
        assert!(plan.arena_bytes >= plan.peak_live_bytes);
        assert!(plan.arena_bytes <= plan.naive_bytes);
        // No live-overlapping slots may share arena bytes.
        for (i, a) in plan.slots.iter().enumerate() {
            for b in plan.slots.iter().skip(i + 1) {
                let live_overlap = a.def <= b.last_use && b.def <= a.last_use;
                if live_overlap && a.bytes > 0 && b.bytes > 0 {
                    let disjoint = a.offset + a.bytes <= b.offset || b.offset + b.bytes <= a.offset;
                    assert!(disjoint);
                }
            }
        }
        // Lifetime sanity: def ≤ last_use, within range.
        for s in lifetimes(&g) {
            assert!(s.def <= s.last_use);
            assert!(s.last_use < g.len());
        }
    }
}

#[test]
fn prop_prepartition_segments_cover_exactly() {
    let mut rng = Rng::seed_from_u64(23);
    for _ in 0..30 {
        let g = random_graph(&mut rng);
        let pp = prepartition(&g);
        let covered: usize = pp.segments.iter().map(|s| s.nodes.len()).sum();
        assert_eq!(covered, g.len());
        let macs: usize = pp.segments.iter().map(|s| s.macs).sum();
        assert_eq!(macs, g.total_macs());
        // Cut tensor sizes match the node shapes.
        for c in &pp.cuts {
            assert_eq!(c.tensor_bytes, g.node(c.node).shape.bytes());
        }
    }
}

#[test]
fn prop_offload_plan_never_worse_than_local() {
    let mut rng = Rng::seed_from_u64(29);
    let topo = Topology::wifi_pair("raspberrypi-4b", "jetson-nx");
    let local = DeviceState {
        snap: ResourceMonitor::new(crowdhmtware::device::device("raspberrypi-4b").unwrap()).idle_snapshot(),
        mem_budget: 4e9,
    };
    let remote = DeviceState {
        snap: ResourceMonitor::new(crowdhmtware::device::device("jetson-nx").unwrap()).idle_snapshot(),
        mem_budget: 8e9,
    };
    for _ in 0..15 {
        let g = random_graph(&mut rng);
        let pp = prepartition(&g);
        let both = plan_offload(&g, &pp, &[local.clone(), remote.clone()], &topo);
        let alone = plan_offload(&g, &pp, std::slice::from_ref(&local), &topo);
        assert!(both.latency_s <= alone.latency_s + 1e-9);
        let covered: usize = both.placements.iter().map(|p| p.segments.len()).sum();
        assert_eq!(covered, pp.segments.len());
    }
}

#[test]
fn prop_profiler_monotone_in_throughput() {
    // Latency/energy finite and positive across the whole device zoo;
    // the strongest device is strictly faster than the weakest.
    let g = backbone(&BackboneConfig::default());
    let cost = CostProfile::of(&g);
    let mut results: Vec<(f64, f64)> = Vec::new();
    for d in all_devices() {
        let snap = ResourceMonitor::new(d.clone()).idle_snapshot();
        let lat = estimate_latency(&cost, &snap);
        let en = estimate_energy(&cost, &snap);
        assert!(lat.total_s > 0.0 && lat.total_s.is_finite(), "{}", d.name);
        assert!(en.total_j > 0.0 && en.total_j.is_finite(), "{}", d.name);
        results.push((d.peak_gmacs, lat.total_s));
    }
    results.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    assert!(results.first().unwrap().1 > results.last().unwrap().1);
}

#[test]
fn prop_variant_spec_apply_is_deterministic() {
    let mut rng = Rng::seed_from_u64(31);
    for _ in 0..10 {
        let g = random_graph(&mut rng);
        let spec = VariantSpec::pair(
            (OperatorKind::LowRank, 0.5),
            (OperatorKind::ChannelScale, [0.25, 0.5, 0.75][rng.gen_index(3)]),
        );
        let a = spec.apply(&g);
        let b = spec.apply(&g);
        assert_eq!(a.total_macs(), b.total_macs());
        assert_eq!(a.len(), b.len());
    }
}

#[test]
fn dynamics_to_profiler_to_loop_pipeline() {
    // Full-stack smoke: dynamics → monitor → profiler → latency/energy
    // stay finite and sane over a long simulated run on battery devices.
    let g = backbone(&BackboneConfig::default());
    let cost = CostProfile::of(&g);
    for d in all_devices().into_iter().filter(|d| d.battery_mah.is_some()).take(4) {
        let mon = ResourceMonitor::new(d.clone());
        let mut sim = DynamicsSim::new(d, 123);
        for _ in 0..100 {
            let ctx: ContextState = sim.tick().clone();
            let snap = mon.sample(&ctx);
            let lat = estimate_latency(&cost, &snap);
            let en = estimate_energy(&cost, &snap);
            assert!(lat.total_s.is_finite() && lat.total_s > 0.0);
            assert!(en.total_j.is_finite() && en.total_j > 0.0);
            sim.consume_energy(en.total_j);
        }
        assert!(sim.state.battery < 1.0);
    }
}
