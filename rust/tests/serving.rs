//! Serving-pool integration suite: concurrent load across workers,
//! mid-stream variant switching, admission-control backpressure, and
//! graceful shutdown — all through the public API with a deterministic
//! mock executor (no built artifacts needed).

use std::collections::HashSet;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;
use crowdhmtware::coordinator::{
    BatcherConfig, DispatchPolicy, Executor, PoolConfig, Rejected, ServingPool,
};

const CLASSES: usize = 4;
const ELEMS: usize = 16;

/// Deterministic fake model: class = argmax over the first CLASSES input
/// values; each batch costs a fixed wall-clock delay.
struct MockExec {
    delay: Duration,
}

impl Executor for MockExec {
    fn batch_sizes(&self, _variant: &str) -> Vec<usize> {
        vec![1, 4, 8]
    }

    fn num_classes(&self) -> usize {
        CLASSES
    }

    fn input_elems(&self) -> usize {
        ELEMS
    }

    fn run(&mut self, _variant: &str, batch: usize, input: &[f32]) -> Result<Vec<f32>> {
        std::thread::sleep(self.delay);
        let mut out = vec![0.0f32; batch * CLASSES];
        for b in 0..batch {
            let row = &input[b * ELEMS..b * ELEMS + CLASSES];
            let total: f32 = row.iter().map(|x| x.exp()).sum();
            for (k, &x) in row.iter().enumerate() {
                out[b * CLASSES + k] = x.exp() / total;
            }
        }
        Ok(out)
    }
}

fn pool(workers: usize, capacity: usize, delay: Duration, batcher: BatcherConfig) -> ServingPool {
    ServingPool::spawn(
        move |_worker| Box::new(MockExec { delay }) as Box<dyn Executor>,
        "base",
        PoolConfig {
            workers,
            queue_capacity: capacity,
            batcher,
            dispatch: DispatchPolicy::LeastQueueDepth,
            ..PoolConfig::default()
        },
    )
}

/// Input whose argmax (and therefore the mock's prediction) is `class`.
fn input_for(class: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; ELEMS];
    v[class % CLASSES] = 4.0;
    v
}

/// ≥256 concurrent requests across ≥4 workers: every response arrives,
/// every prediction is correct, ids are unique, and the pool accounting
/// satisfies served + rejected == submitted (with zero rejections at
/// this capacity).
#[test]
fn concurrent_load_across_workers() {
    const THREADS: usize = 8;
    const PER_THREAD: usize = 32;
    const TOTAL: usize = THREADS * PER_THREAD; // 256

    let p = Arc::new(pool(
        4,
        1024,
        Duration::from_micros(400),
        BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(2) },
    ));
    let mut joins = Vec::new();
    for t in 0..THREADS {
        let p = Arc::clone(&p);
        joins.push(std::thread::spawn(move || {
            let mut got = Vec::new();
            let mut rxs = Vec::new();
            for i in 0..PER_THREAD {
                let class = (t * PER_THREAD + i) % CLASSES;
                let rx = p.submit(input_for(class)).expect("capacity is ample");
                rxs.push((class, rx));
            }
            for (want, rx) in rxs {
                let resp = rx.recv_timeout(Duration::from_secs(10)).expect("no lost responses");
                assert_eq!(resp.pred, want, "wrong prediction");
                got.push((resp.id, resp.worker));
            }
            got
        }));
    }
    let mut ids = HashSet::new();
    let mut workers_used = HashSet::new();
    let mut total = 0usize;
    for j in joins {
        for (id, worker) in j.join().expect("client thread") {
            assert!(ids.insert(id), "duplicate response id {id}");
            workers_used.insert(worker);
            total += 1;
        }
    }
    assert_eq!(total, TOTAL);
    assert!(workers_used.len() >= 2, "load stayed on {workers_used:?}");

    let stats = p_unwrap(p).shutdown();
    assert_eq!(stats.served(), TOTAL);
    assert_eq!(stats.rejected(), 0);
    assert_eq!(stats.failed(), 0);
    assert_eq!(stats.served() + stats.rejected(), TOTAL);
    assert_eq!(stats.per_worker.len(), 4);
}

fn p_unwrap(p: Arc<ServingPool>) -> ServingPool {
    Arc::try_unwrap(p).unwrap_or_else(|_| panic!("pool still shared"))
}

/// Variant switch mid-stream: once `switch_variant` has returned (every
/// worker acked), no subsequently admitted request is answered with the
/// pre-switch variant, and generations are consistent with variants on
/// every response including the in-flight ones.
#[test]
fn variant_switch_mid_stream() {
    let p = Arc::new(pool(
        4,
        4096,
        Duration::from_micros(800),
        BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
    ));

    // Background load running across the switch.
    let bg = {
        let p = Arc::clone(&p);
        std::thread::spawn(move || {
            let mut rxs = Vec::new();
            for i in 0..128 {
                if let Ok(rx) = p.submit(input_for(i)) {
                    rxs.push(rx);
                }
                std::thread::sleep(Duration::from_micros(50));
            }
            rxs.into_iter()
                .map(|rx| rx.recv_timeout(Duration::from_secs(10)).expect("bg response"))
                .collect::<Vec<_>>()
        })
    };
    std::thread::sleep(Duration::from_millis(2));

    let gen = p.switch_variant("upgraded");
    assert_eq!(gen, 1);

    // Everything admitted after the ack must serve the new variant.
    let mut rxs = Vec::new();
    for i in 0..64 {
        rxs.push(p.submit(input_for(i)).expect("admitted"));
    }
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(10)).expect("post-switch response");
        assert_eq!(resp.variant, "upgraded", "stale variant after acknowledged switch");
        assert_eq!(resp.generation, gen);
    }

    // In-flight responses are internally consistent: generation 0 ⇔ old
    // variant, generation 1 ⇔ new variant. Nothing is lost.
    let bg_responses = bg.join().expect("bg thread");
    assert_eq!(bg_responses.len(), 128);
    for resp in &bg_responses {
        match resp.generation {
            0 => assert_eq!(resp.variant, "base"),
            1 => assert_eq!(resp.variant, "upgraded"),
            g => panic!("unexpected generation {g}"),
        }
    }

    let stats = p_unwrap(p).shutdown();
    assert_eq!(stats.served(), 128 + 64);
    assert_eq!(stats.switches(), 1, "every worker applied exactly one switch");
}

/// Backpressure: tiny bounded queues + slow workers reject the overflow
/// with the typed verdict, every admitted request completes, and
/// served + rejected == submitted exactly.
#[test]
fn backpressure_accounting() {
    const SUBMITTED: usize = 512;
    let p = pool(
        4,
        4,
        Duration::from_millis(2),
        BatcherConfig { max_batch: 4, max_wait: Duration::from_micros(200) },
    );
    let mut admitted = Vec::new();
    let mut rejected = 0usize;
    for i in 0..SUBMITTED {
        match p.submit(input_for(i)) {
            Ok(rx) => admitted.push(rx),
            Err(r @ Rejected { capacity, .. }) => {
                assert_eq!(capacity, 4);
                assert!(r.queue_depth >= capacity || r.worker.is_none());
                rejected += 1;
            }
        }
    }
    assert!(rejected > 0, "flood must trip admission control");
    assert!(!admitted.is_empty(), "some requests must be admitted");
    for rx in &admitted {
        rx.recv_timeout(Duration::from_secs(30)).expect("admitted request must complete");
    }
    let stats = p.shutdown();
    assert_eq!(stats.served(), admitted.len());
    assert_eq!(stats.rejected(), rejected);
    assert_eq!(stats.served() + stats.rejected(), SUBMITTED);
}

/// Graceful shutdown drains in-flight requests: a long batch window keeps
/// requests parked in the batchers; shutdown must flush every one of
/// them with a correct answer rather than dropping them.
#[test]
fn graceful_shutdown_drains_in_flight() {
    let p = pool(
        4,
        256,
        Duration::from_micros(300),
        // Window far longer than the test: only the drain can flush.
        BatcherConfig { max_batch: 64, max_wait: Duration::from_secs(600) },
    );
    let mut rxs = Vec::new();
    for i in 0..48 {
        rxs.push((i % CLASSES, p.submit(input_for(i)).expect("admitted")));
    }
    let stats = p.shutdown();
    assert_eq!(stats.served(), 48, "drain must serve every in-flight request");
    assert_eq!(stats.failed(), 0);
    for (want, rx) in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(5)).expect("drained response");
        assert_eq!(resp.pred, want);
    }
}

/// Pool-vs-single throughput on the mock executor: with a fixed per-batch
/// cost, four workers must sustain strictly higher throughput than one.
/// Wall-clock sensitive, hence `#[ignore]` — run explicitly with
/// `cargo test --test serving -- --ignored`.
#[test]
#[ignore]
fn pool_outperforms_single_worker() {
    fn throughput(workers: usize) -> f64 {
        const N: usize = 256;
        let p = pool(
            workers,
            4096,
            Duration::from_millis(2),
            BatcherConfig { max_batch: 8, max_wait: Duration::from_micros(500) },
        );
        let t0 = Instant::now();
        let rxs: Vec<_> = (0..N).map(|i| p.submit(input_for(i)).expect("admitted")).collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(30)).expect("response");
        }
        let elapsed = t0.elapsed().as_secs_f64();
        let stats = p.shutdown();
        assert_eq!(stats.served(), N);
        N as f64 / elapsed
    }

    let single = throughput(1);
    let quad = throughput(4);
    assert!(
        quad > single,
        "pool must sustain strictly higher throughput: 4 workers {quad:.0} req/s vs 1 worker {single:.0} req/s"
    );
}
