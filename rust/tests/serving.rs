//! Serving-pool integration suite: concurrent load across workers,
//! mid-stream variant switching, admission-control backpressure, graceful
//! shutdown, priority lanes, pool-vs-single throughput, work stealing of
//! a wedged worker's stranded backlog (with the priority lane pinned to
//! its admitting worker), and the closed cross-level loop — a calibrated
//! control plane converging to the variant the *measured* latencies
//! support, and the AIMD sizer widening and narrowing the pool from
//! telemetry. All through the public API with deterministic mock
//! executors (no built artifacts needed).

use std::collections::{HashMap, HashSet};
use std::time::{Duration, Instant};

use crowdhmtware::sync::{lock_or_recover, thread, Arc, Mutex};

use anyhow::Result;
use crowdhmtware::compress::{OperatorKind, VariantSpec};
use crowdhmtware::coordinator::{
    BatcherConfig, DispatchPolicy, Executor, Lane, PoolConfig, Rejected, ServingPool,
    StealConfig, Submission,
};
use crowdhmtware::device::{device, ResourceMonitor};
use crowdhmtware::engine::EngineConfig;
use crowdhmtware::models::{backbone, BackboneConfig};
use crowdhmtware::optimizer::{
    evaluate, Actuator, AdaptLoop, Budgets, Candidate, PoolSizer, PoolSizerConfig, SizeDecision,
};

const CLASSES: usize = 4;
const ELEMS: usize = 16;

/// Deterministic fake model: class = argmax over the first CLASSES input
/// values; each batch costs a fixed wall-clock delay.
struct MockExec {
    delay: Duration,
}

impl Executor for MockExec {
    fn batch_sizes(&self, _variant: &str) -> Vec<usize> {
        vec![1, 4, 8]
    }

    fn num_classes(&self) -> usize {
        CLASSES
    }

    fn input_elems(&self) -> usize {
        ELEMS
    }

    fn run(&mut self, _variant: &str, batch: usize, input: &[f32]) -> Result<Vec<f32>> {
        thread::sleep(self.delay);
        let mut out = vec![0.0f32; batch * CLASSES];
        for b in 0..batch {
            let row = &input[b * ELEMS..b * ELEMS + CLASSES];
            let total: f32 = row.iter().map(|x| x.exp()).sum();
            for (k, &x) in row.iter().enumerate() {
                out[b * CLASSES + k] = x.exp() / total;
            }
        }
        Ok(out)
    }
}

fn pool(workers: usize, capacity: usize, delay: Duration, batcher: BatcherConfig) -> ServingPool {
    ServingPool::spawn(
        move |_worker| Box::new(MockExec { delay }) as Box<dyn Executor>,
        "base",
        PoolConfig {
            workers,
            queue_capacity: capacity,
            batcher,
            dispatch: DispatchPolicy::LeastQueueDepth,
            ..PoolConfig::default()
        },
    )
}

/// Input whose argmax (and therefore the mock's prediction) is `class`.
fn input_for(class: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; ELEMS];
    v[class % CLASSES] = 4.0;
    v
}

/// ≥256 concurrent requests across ≥4 workers: every response arrives,
/// every prediction is correct, ids are unique, and the pool accounting
/// satisfies served + rejected == submitted (with zero rejections at
/// this capacity).
#[test]
fn concurrent_load_across_workers() {
    const THREADS: usize = 8;
    const PER_THREAD: usize = 32;
    const TOTAL: usize = THREADS * PER_THREAD; // 256

    let p = Arc::new(pool(
        4,
        1024,
        Duration::from_micros(400),
        BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(2) },
    ));
    let mut joins = Vec::new();
    for t in 0..THREADS {
        let p = Arc::clone(&p);
        joins.push(thread::spawn(move || {
            let mut got = Vec::new();
            let mut rxs = Vec::new();
            for i in 0..PER_THREAD {
                let class = (t * PER_THREAD + i) % CLASSES;
                let rx =
                    p.submit_with(Submission::new(input_for(class))).expect("capacity is ample");
                rxs.push((class, rx));
            }
            for (want, rx) in rxs {
                let resp = rx.recv_timeout(Duration::from_secs(10)).expect("no lost responses");
                assert_eq!(resp.pred, want, "wrong prediction");
                got.push((resp.id, resp.worker));
            }
            got
        }));
    }
    let mut ids = HashSet::new();
    let mut workers_used = HashSet::new();
    let mut total = 0usize;
    for j in joins {
        for (id, worker) in j.join().expect("client thread") {
            assert!(ids.insert(id), "duplicate response id {id}");
            workers_used.insert(worker);
            total += 1;
        }
    }
    assert_eq!(total, TOTAL);
    assert!(workers_used.len() >= 2, "load stayed on {workers_used:?}");

    let stats = p_unwrap(p).shutdown();
    assert_eq!(stats.served(), TOTAL);
    assert_eq!(stats.rejected(), 0);
    assert_eq!(stats.failed(), 0);
    assert_eq!(stats.served() + stats.rejected(), TOTAL);
    assert_eq!(stats.per_worker.len(), 4);
}

fn p_unwrap(p: Arc<ServingPool>) -> ServingPool {
    Arc::try_unwrap(p).unwrap_or_else(|_| panic!("pool still shared"))
}

/// Variant switch mid-stream: once `switch_variant` has returned (every
/// worker acked), no subsequently admitted request is answered with the
/// pre-switch variant, and generations are consistent with variants on
/// every response including the in-flight ones.
#[test]
fn variant_switch_mid_stream() {
    let p = Arc::new(pool(
        4,
        4096,
        Duration::from_micros(800),
        BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
    ));

    // Background load running across the switch.
    let bg = {
        let p = Arc::clone(&p);
        thread::spawn(move || {
            let mut rxs = Vec::new();
            for i in 0..128 {
                if let Ok(rx) = p.submit_with(Submission::new(input_for(i))) {
                    rxs.push(rx);
                }
                thread::sleep(Duration::from_micros(50));
            }
            rxs.into_iter()
                .map(|rx| rx.recv_timeout(Duration::from_secs(10)).expect("bg response"))
                .collect::<Vec<_>>()
        })
    };
    thread::sleep(Duration::from_millis(2));

    let gen = p.switch_variant("upgraded");
    assert_eq!(gen, 1);

    // Everything admitted after the ack must serve the new variant.
    let mut rxs = Vec::new();
    for i in 0..64 {
        rxs.push(p.submit_with(Submission::new(input_for(i))).expect("admitted"));
    }
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(10)).expect("post-switch response");
        assert_eq!(&*resp.variant, "upgraded", "stale variant after acknowledged switch");
        assert_eq!(resp.generation, gen);
    }

    // In-flight responses are internally consistent: generation 0 ⇔ old
    // variant, generation 1 ⇔ new variant. Nothing is lost.
    let bg_responses = bg.join().expect("bg thread");
    assert_eq!(bg_responses.len(), 128);
    for resp in &bg_responses {
        match resp.generation {
            0 => assert_eq!(&*resp.variant, "base"),
            1 => assert_eq!(&*resp.variant, "upgraded"),
            g => panic!("unexpected generation {g}"),
        }
    }

    let stats = p_unwrap(p).shutdown();
    assert_eq!(stats.served(), 128 + 64);
    assert_eq!(stats.switches(), 1, "every worker applied exactly one switch");
}

/// Backpressure: tiny bounded queues + slow workers reject the overflow
/// with the typed verdict, every admitted request completes, and
/// served + rejected == submitted exactly.
#[test]
fn backpressure_accounting() {
    const SUBMITTED: usize = 512;
    let p = pool(
        4,
        4,
        Duration::from_millis(2),
        BatcherConfig { max_batch: 4, max_wait: Duration::from_micros(200) },
    );
    let mut admitted = Vec::new();
    let mut rejected = 0usize;
    for i in 0..SUBMITTED {
        match p.submit_with(Submission::new(input_for(i))) {
            Ok(rx) => admitted.push(rx),
            Err(r @ Rejected { capacity, .. }) => {
                assert_eq!(capacity, 4);
                assert!(r.queue_depth >= capacity || r.worker.is_none());
                rejected += 1;
            }
        }
    }
    assert!(rejected > 0, "flood must trip admission control");
    assert!(!admitted.is_empty(), "some requests must be admitted");
    for rx in &admitted {
        rx.recv_timeout(Duration::from_secs(30)).expect("admitted request must complete");
    }
    let stats = p.shutdown();
    assert_eq!(stats.served(), admitted.len());
    assert_eq!(stats.rejected(), rejected);
    assert_eq!(stats.served() + stats.rejected(), SUBMITTED);
}

/// Graceful shutdown drains in-flight requests: a long batch window keeps
/// requests parked in the batchers; shutdown must flush every one of
/// them with a correct answer rather than dropping them.
#[test]
fn graceful_shutdown_drains_in_flight() {
    let p = pool(
        4,
        256,
        Duration::from_micros(300),
        // Window far longer than the test: only the drain can flush.
        BatcherConfig { max_batch: 64, max_wait: Duration::from_secs(600) },
    );
    let mut rxs = Vec::new();
    for i in 0..48 {
        rxs.push((i % CLASSES, p.submit_with(Submission::new(input_for(i))).expect("admitted")));
    }
    let stats = p.shutdown();
    assert_eq!(stats.served(), 48, "drain must serve every in-flight request");
    assert_eq!(stats.failed(), 0);
    for (want, rx) in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(5)).expect("drained response");
        assert_eq!(resp.pred, want);
    }
}

/// Priority lane: with a single worker chewing through a normal-lane
/// backlog one fixed-cost batch at a time, a priority submission arriving
/// last must overtake the queued normal requests — its measured latency
/// beats the tail of the backlog, and telemetry tags both lanes.
#[test]
fn priority_lane_overtakes_backlog() {
    let p = pool(
        1,
        64,
        Duration::from_millis(3),
        BatcherConfig { max_batch: 1, max_wait: Duration::from_micros(100) },
    );
    let normals: Vec<_> =
        (0..8).map(|i| p.submit_with(Submission::new(input_for(i))).expect("admitted")).collect();
    let prio = p.submit_with(Submission::new(input_for(1)).lane(Lane::High)).expect("admitted");

    let prio_resp = prio.recv_timeout(Duration::from_secs(10)).expect("priority response");
    assert_eq!(prio_resp.lane, Lane::High);
    assert_eq!(prio_resp.pred, 1);
    let normal_lats: Vec<Duration> = normals
        .into_iter()
        .map(|rx| {
            let r = rx.recv_timeout(Duration::from_secs(10)).expect("normal response");
            assert_eq!(r.lane, Lane::Normal);
            r.latency
        })
        .collect();
    let slowest_normal = normal_lats.iter().max().copied().unwrap();
    assert!(
        prio_resp.latency < slowest_normal,
        "priority ({:?}) must overtake the normal backlog tail ({:?})",
        prio_resp.latency,
        slowest_normal
    );

    let tel = p.telemetry_snapshot();
    assert_eq!(tel.lanes[Lane::High.index()].served, 1);
    assert_eq!(tel.lanes[Lane::Normal.index()].served, 8);
    assert!(tel.lanes[Lane::High.index()].p50_s > 0.0, "lane latencies are recorded");
    assert_eq!(p.shutdown().served(), 9);
}

/// Pool-vs-single throughput with the stub executor's fixed per-batch
/// cost: each request costs exactly one 2 ms batch (max_batch = 1), so a
/// fixed submission window bounds a single worker at ~window/2ms serves
/// while four workers overlap batches. Asserts on the served-count
/// ratio, not on wall-clock latency measurements.
#[test]
fn pool_outperforms_single_worker() {
    fn served_in_window(workers: usize, window: Duration) -> usize {
        let p = pool(
            workers,
            4,
            Duration::from_millis(2),
            BatcherConfig { max_batch: 1, max_wait: Duration::from_micros(100) },
        );
        let deadline = Instant::now() + window;
        let mut rxs = Vec::new();
        while Instant::now() < deadline {
            match p.submit_with(Submission::new(input_for(0))) {
                Ok(rx) => rxs.push(rx),
                // Queues full: the workers are saturated; back off briefly.
                Err(_) => thread::sleep(Duration::from_micros(200)),
            }
        }
        let stats = p.shutdown();
        for rx in rxs {
            let _ = rx.recv_timeout(Duration::from_secs(10));
        }
        stats.served()
    }

    let window = Duration::from_millis(400);
    let single = served_in_window(1, window);
    let quad = served_in_window(4, window);
    assert!(
        quad >= 2 * single,
        "4 workers must serve ≥2× a single worker in the same window: {quad} vs {single}"
    );
}

/// Work stealing (acceptance): one worker is wedged by an artificially
/// slow batch with its normal lane pre-loaded; the idle workers that
/// then join the pool steal and drain the stranded requests — all of
/// them complete in a fraction of the wedged worker's serial drain
/// time, the hub's steal counters are nonzero, and a priority request
/// parked on the victim is *not* stolen (the lane-ordering invariant:
/// priority requests never migrate).
#[test]
fn idle_workers_steal_stranded_backlog() {
    const STRANDED: usize = 12;
    let slow = Duration::from_millis(250);
    // Worker 0 (the victim) pays 250 ms per batch; dynamically spawned
    // workers are fast.
    let p = ServingPool::spawn(
        move |worker| {
            let delay = if worker == 0 { slow } else { Duration::from_millis(1) };
            Box::new(MockExec { delay }) as Box<dyn Executor>
        },
        "base",
        PoolConfig {
            workers: 1,
            queue_capacity: 64,
            batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_micros(100) },
            ..PoolConfig::default()
        },
    );
    let t0 = Instant::now();
    // Wedge the only worker: it absorbs this request and disappears into
    // a 250 ms batch.
    let wedge = p.submit_with(Submission::new(input_for(0))).expect("admitted");
    thread::sleep(Duration::from_millis(30));
    // Pre-load the victim's queue while it is stuck, priority last.
    let stranded: Vec<_> = (0..STRANDED)
        .map(|i| (i % CLASSES, p.submit_with(Submission::new(input_for(i))).expect("admitted")))
        .collect();
    let prio = p.submit_with(Submission::new(input_for(1)).lane(Lane::High)).expect("admitted");
    // Three idle fast workers join: the steal phase must move the
    // stranded normal lane onto them.
    p.set_workers(4);

    for (want, rx) in stranded {
        let r = rx.recv_timeout(Duration::from_secs(5)).expect("stranded request must complete");
        assert_eq!(r.pred, want);
    }
    let normal_drain = t0.elapsed();
    // Serial drain on the victim would cost ≥ (1 wedge + 12 stranded) ×
    // 250 ms = 3.25 s; stolen requests must beat that by a wide margin.
    assert!(
        normal_drain < Duration::from_millis(2000),
        "stranded normal lane took {normal_drain:?} — was anything stolen?"
    );

    // The priority request stays parked on (and is served by) the
    // worker that admitted it.
    let pr = prio.recv_timeout(Duration::from_secs(5)).expect("priority response");
    assert_eq!(pr.lane, Lane::High);
    assert_eq!(pr.worker, 0, "priority requests must never migrate");
    wedge.recv_timeout(Duration::from_secs(5)).expect("wedge response");

    let tel = p.telemetry_snapshot();
    let victim = tel.per_worker.iter().find(|w| w.worker == 0).expect("victim slot");
    assert!(victim.stolen_from >= 1, "the victim's lane was never stolen from");
    let steals: usize = tel.per_worker.iter().map(|w| w.steals).sum();
    assert!(steals >= victim.stolen_from, "every stolen request has a thief");
    assert_eq!(tel.steals, steals, "snapshot total mirrors the per-worker counters");

    let stats = p.shutdown();
    assert_eq!(stats.served(), STRANDED + 2, "nothing lost in migration");
    assert_eq!(stats.failed(), 0);
}

/// Stealing can be disabled: the same wedged-victim topology drains
/// serially and the steal counters stay at zero (the bench relies on
/// this toggle to show the head-of-line difference).
#[test]
fn steal_disabled_keeps_lanes_private() {
    let p = ServingPool::spawn(
        move |worker| {
            let delay =
                if worker == 0 { Duration::from_millis(40) } else { Duration::from_millis(1) };
            Box::new(MockExec { delay }) as Box<dyn Executor>
        },
        "base",
        PoolConfig {
            workers: 1,
            queue_capacity: 64,
            batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_micros(100) },
            steal: StealConfig { enabled: false, ..StealConfig::default() },
            ..PoolConfig::default()
        },
    );
    let rxs: Vec<_> =
        (0..6).map(|i| p.submit_with(Submission::new(input_for(i))).expect("admitted")).collect();
    thread::sleep(Duration::from_millis(30));
    p.set_workers(3);
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(10)).expect("response");
    }
    let tel = p.telemetry_snapshot();
    assert_eq!(tel.steals, 0, "disabled stealing must never migrate a request");
    p.shutdown();
}

// ── the closed cross-level loop (acceptance) ───────────────────────────

/// Executor whose per-batch cost is looked up by variant from a shared,
/// test-controlled table — the "real device" whose behavior the cost
/// model mispredicts.
struct SleepExec {
    sleeps: Arc<Mutex<HashMap<String, Duration>>>,
}

impl Executor for SleepExec {
    fn batch_sizes(&self, _v: &str) -> Vec<usize> {
        vec![1, 4, 8]
    }

    fn num_classes(&self) -> usize {
        CLASSES
    }

    fn input_elems(&self) -> usize {
        ELEMS
    }

    fn run(&mut self, variant: &str, batch: usize, _input: &[f32]) -> Result<Vec<f32>> {
        let delay = lock_or_recover(&self.sleeps)
            .get(variant)
            .copied()
            .unwrap_or(Duration::from_micros(500));
        thread::sleep(delay);
        Ok(vec![1.0 / CLASSES as f32; batch * CLASSES])
    }
}

/// A deliberately mispredicting cost model, corrected by telemetry: the
/// control plane first picks a variant whose *predicted* latency fits the
/// budget; the pool then measures it running far over budget (the test
/// makes the executor sleep 2.5× the budget per batch for exactly that
/// variant). Within a few telemetry-fed ticks the calibrator's
/// observed/predicted ratio pushes the mispredicted variant out of the
/// feasible set and the loop converges to — and stays on — the variant
/// whose measured latency actually fits. Decided from measurements, not
/// predictions.
#[test]
fn calibrated_control_plane_converges_to_measured_choice() {
    let snap = ResourceMonitor::new(device("jetson-nx").unwrap()).idle_snapshot();
    let g = backbone(&BackboneConfig::default());
    let base_acc = 80.0;
    let front = vec![
        Candidate::baseline(),
        Candidate {
            spec: VariantSpec::single(OperatorKind::ChannelScale, 0.5),
            engine: EngineConfig::none(),
            offload: false,
        },
    ];
    let labels: Vec<String> = front.iter().map(|c| c.spec.detailed_label()).collect();
    let predicted: Vec<f64> = front
        .iter()
        .map(|c| evaluate(&g, c, base_acc, &snap, 0.0, true).metrics.latency_s)
        .collect();
    // Both candidates fit the budget on *predicted* latency.
    let budget = (2.0 * predicted.iter().cloned().fold(0.0, f64::max)).max(0.030);

    let sleeps: Arc<Mutex<HashMap<String, Duration>>> = Arc::new(Mutex::new(HashMap::new()));
    let sleeps_exec = Arc::clone(&sleeps);
    let p = ServingPool::spawn(
        move |_| Box::new(SleepExec { sleeps: Arc::clone(&sleeps_exec) }) as Box<dyn Executor>,
        "cold-start",
        PoolConfig {
            workers: 2,
            queue_capacity: 64,
            batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(1) },
            ..PoolConfig::default()
        },
    );
    let mut l = AdaptLoop::new(
        g,
        base_acc,
        front,
        Budgets { latency_s: budget, memory_bytes: f64::INFINITY },
    );

    // Tick 1: no telemetry yet — the choice is prediction-only.
    l.tick_with_telemetry(&snap, &p.telemetry_snapshot(), &p);
    let first = l.current().unwrap().candidate.spec.detailed_label();
    let other = labels.iter().find(|x| **x != first).unwrap().clone();

    // The device disagrees with the model: the deployed variant actually
    // costs 2.5× the *budget* per batch; the alternative is honest.
    lock_or_recover(&sleeps).insert(first.clone(), Duration::from_secs_f64(budget * 2.5));
    lock_or_recover(&sleeps).insert(other.clone(), Duration::from_millis(1));

    let mut converged_at = None;
    for tick in 1..=6 {
        // Serve sequentially so every request forms its own batch: the
        // per-variant telemetry sample (the batch's execution wall time)
        // is then exactly the executor's per-request cost, keeping the
        // measured ratio deterministic.
        for i in 0..4 {
            let rx = p.submit_with(Submission::new(input_for(i))).expect("admitted");
            rx.recv_timeout(Duration::from_secs(20)).expect("response");
        }
        let tel = p.telemetry_snapshot();
        l.tick_with_telemetry(&snap, &tel, &p);
        let now = l.current().unwrap().candidate.spec.detailed_label();
        if converged_at.is_none() && now == other {
            converged_at = Some(tick);
        }
    }
    let tick = converged_at.expect("control plane never abandoned the mispredicted variant");
    assert!(tick <= 4, "convergence took {tick} telemetry ticks");
    // Converged *and stable*: the final choice is still the honest variant,
    // its calibrated latency fits the budget, and the pool is serving it.
    assert_eq!(l.current().unwrap().candidate.spec.detailed_label(), other);
    assert!(l.current().unwrap().metrics.latency_s <= budget);
    let rx = p.submit_with(Submission::new(input_for(0))).expect("admitted");
    assert_eq!(&*rx.recv_timeout(Duration::from_secs(10)).expect("response").variant, other);
    let ratio = l.calibrator.ratio(&first);
    assert!(ratio > 2.0, "the mispredicted variant's measured ratio must be learned, got {ratio}");
    p.shutdown();
}

/// The AIMD arm of the control plane on a live pool: sustained backlog
/// (measured queue occupancy) grows the worker set additively; admission
/// rejections (the measured congestion signal) shrink it multiplicatively
/// back to the floor. Width decisions come from the telemetry snapshot,
/// never from predictions.
#[test]
fn aimd_sizer_widens_then_narrows_live_pool() {
    let p = pool(
        1,
        16,
        Duration::from_millis(3),
        BatcherConfig { max_batch: 8, max_wait: Duration::from_micros(200) },
    );
    let snap = ResourceMonitor::new(device("raspberrypi-4b").unwrap()).idle_snapshot(); // 4 cores
    let mut sizer = PoolSizer::new(PoolSizerConfig {
        min_workers: 1,
        max_workers: 8,
        grow_step: 1,
        shrink_factor: 0.5,
        occupancy_grow: 0.25,
    });

    // Growth episode: each round submits a backlog (half the live
    // capacity), snapshots telemetry while it is queued, and lets the
    // sizer decide.
    let mut widths = vec![p.num_workers()];
    for _ in 0..5 {
        let burst = 8 * p.num_workers();
        let rxs: Vec<_> = (0..burst)
            .map(|i| p.submit_with(Submission::new(input_for(i))).expect("admitted"))
            .collect();
        let tel = p.telemetry_snapshot();
        if let Some(target) = sizer.decide(&tel, &snap, f64::INFINITY).target() {
            Actuator::set_workers(&p, target);
        }
        widths.push(p.num_workers());
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(20)).expect("response");
        }
    }
    assert!(
        p.num_workers() >= 3,
        "sustained load must widen the pool: widths {widths:?}"
    );
    assert!(widths.windows(2).all(|w| w[1] >= w[0]), "growth is monotone: {widths:?}");
    assert!(
        widths.windows(2).all(|w| w[1] - w[0] <= 1),
        "growth is additive (one step per tick): {widths:?}"
    );

    // Congestion episodes: flood past capacity to force rejections, then
    // let the sizer react. Multiplicative decrease walks the width down
    // to the floor within a couple of episodes.
    let mut shrinks = 0;
    for _ in 0..3 {
        if p.num_workers() == 1 {
            break;
        }
        let flood = 64 * p.num_workers();
        let mut rxs = Vec::new();
        let mut rejected = 0usize;
        for i in 0..flood {
            match p.submit_with(Submission::new(input_for(i))) {
                Ok(rx) => rxs.push(rx),
                Err(_) => rejected += 1,
            }
        }
        assert!(rejected > 0, "flood must trip admission control");
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(30)).expect("response");
        }
        let before = p.num_workers();
        let tel = p.telemetry_snapshot();
        match sizer.decide(&tel, &snap, f64::INFINITY) {
            SizeDecision::Shrink(target) => {
                Actuator::set_workers(&p, target);
                shrinks += 1;
                assert!(p.num_workers() < before, "shrink must narrow the pool");
                assert!(
                    p.num_workers() <= (before as f64 * 0.5).ceil() as usize,
                    "decrease is multiplicative: {before} → {}",
                    p.num_workers()
                );
            }
            d => panic!("rejections must shrink, got {d:?}"),
        }
    }
    assert!(shrinks >= 1, "at least one multiplicative shrink episode");
    assert_eq!(p.num_workers(), 1, "repeated congestion walks the pool to the floor");

    // Lifetime accounting survived every resize.
    let tel = p.telemetry_snapshot();
    let stats = p.shutdown();
    assert_eq!(stats.served(), tel.served, "live telemetry matches shutdown stats");
    assert!(stats.rejected() > 0);
}
