//! Integration regressions for the open-loop scenario harness: scripted
//! fleet dynamics landing while trace-driven load is in flight against
//! the live router + pool stack.

use std::time::Duration;

use crowdhmtware::coordinator::{
    BatcherConfig, ClassConfig, PoolConfig, ShardRouterConfig, TenancyConfig,
};
use crowdhmtware::workload::{
    run_scenario, ArrivalSchedule, FleetEvent, FleetScript, MaintainController, RequestMix,
    RetryPolicy, Scenario, ScenarioStack, StackConfig, Trace,
};

const ELEMS: usize = 32;

fn stack() -> ScenarioStack {
    ScenarioStack::spawn(StackConfig {
        classes: 4,
        elems: ELEMS,
        batch_sizes: vec![1, 4, 8],
        local_delay: Duration::from_millis(1),
        variant: "v".to_string(),
        pool: PoolConfig {
            workers: 2,
            queue_capacity: 64,
            batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_micros(500) },
            ..PoolConfig::default()
        },
        router: ShardRouterConfig { peer_capacity: 8, ..ShardRouterConfig::default() },
    })
}

/// The harness's reason to exist: a peer that dies *while carrying
/// live open-loop traffic* must not strand a single admitted caller —
/// `kill_peer`'s dead-lane drain answers everything already on the
/// link, and the dead slot never routes again.
#[test]
fn scripted_peer_death_fails_no_inflight_callers() {
    let stack = stack();
    // Strongly preferred peer (tiny prior, fast link): it is carrying
    // traffic at the moment the script kills it.
    stack.add_peer("edge", Duration::from_millis(1), 200.0, 1.0, 0.0005);
    let trace = Trace::generate(
        &ArrivalSchedule::Poisson { rate_hz: 600.0 },
        &RequestMix::default(),
        Duration::from_millis(600),
        ELEMS,
        7,
    );
    let scenario = Scenario::new("peer_death", trace).with_script(
        FleetScript::new().at(Duration::from_millis(300), FleetEvent::PeerDeath { peer: 0 }),
    );
    let report = run_scenario(&stack, &scenario, &mut MaintainController);

    assert_eq!(report.load.failed, 0, "dead-lane drain must answer every admitted caller");
    assert_eq!(report.load.completed + report.load.rejected, report.load.offered);
    assert_eq!(report.adaptation.peers_killed, 1);
    let stats = stack.router().shard_stats();
    assert!(stats.peers[0].dead);
    assert!(stats.peers[0].routed > 0, "the peer must have carried traffic before dying");
    stack.shutdown();
}

/// Decision-level dynamics mid-run: a variant switch and a device
/// drift land under load without failing requests, and the scenario
/// window attributes exactly one switch to the run.
#[test]
fn variant_switch_and_drift_land_under_open_loop_load() {
    let stack = stack();
    let trace = Trace::generate(
        &ArrivalSchedule::Poisson { rate_hz: 500.0 },
        &RequestMix {
            priority_share: 0.1,
            hot_share: 0.0,
            sizes: vec![(ELEMS, 1.0)],
            ..RequestMix::default()
        },
        Duration::from_millis(400),
        ELEMS,
        11,
    );
    let scenario = Scenario::new("switch_under_load", trace).with_script(
        FleetScript::new()
            .at(Duration::from_millis(150), FleetEvent::DeviceDrift { factor: 1.5 })
            .at(
                Duration::from_millis(200),
                FleetEvent::VariantSwitch { variant: "e3".to_string() },
            ),
    );
    let report = run_scenario(&stack, &scenario, &mut MaintainController);

    assert_eq!(report.load.failed, 0);
    assert_eq!(report.load.completed + report.load.rejected, report.load.offered);
    assert_eq!(report.adaptation.switches, 1);
    assert!(report.window.switches >= 1, "worker slots must have applied the new variant");
    stack.shutdown();
}

/// A scripted retry storm against a governed tenant: every rejection is
/// re-offered (the scenario opts in — the driver default stays
/// no-retry), and the tenant's **retry budget** clamps the
/// amplification to `retry_frac × fresh admits`, asserted from the
/// windowed `SnapshotDelta`.
#[test]
fn retry_budget_clamps_scripted_retry_storm() {
    const RETRY_FRAC: f64 = 0.25;
    let mut cfg = StackConfig {
        classes: 4,
        elems: ELEMS,
        batch_sizes: vec![1, 4, 8],
        local_delay: Duration::from_millis(1),
        variant: "v".to_string(),
        pool: PoolConfig {
            workers: 2,
            queue_capacity: 64,
            batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_micros(500) },
            ..PoolConfig::default()
        },
        router: ShardRouterConfig { peer_capacity: 8, ..ShardRouterConfig::default() },
    };
    // The storm tenant's contract admits ~100 req/s fresh; the trace
    // offers ~800 req/s, so most submissions bounce off the token
    // bucket and the scripted retries hammer the front door again.
    cfg.pool.tenancy = TenancyConfig {
        classes: vec![ClassConfig {
            tenant: "storm".to_string(),
            rate_hz: 100.0,
            burst: 8,
            reserve_frac: 0.0,
            retry_frac: RETRY_FRAC,
        }],
    };
    let stack = ScenarioStack::spawn(cfg);
    let trace = Trace::generate(
        &ArrivalSchedule::Poisson { rate_hz: 800.0 },
        &RequestMix::default(),
        Duration::from_millis(600),
        ELEMS,
        13,
    )
    .tagged("storm");
    let mut scenario = Scenario::new("retry_storm", trace);
    scenario.openloop.retry = Some(RetryPolicy { attempts: 2 });
    let report = run_scenario(&stack, &scenario, &mut MaintainController);

    let d = &report.window.per_tenant["storm"];
    let l = &report.load.per_tenant["storm"];
    // Exactly-one-outcome conservation across fresh + retry submissions.
    assert_eq!(
        d.admitted + d.rejected + d.retry_spent,
        l.offered + l.retries_submitted,
        "per-tenant conservation broke"
    );
    assert!(d.admitted > 0, "the contract must admit the in-rate slice");
    assert!(l.retries_submitted > 0, "the storm must have fired");
    assert_eq!(l.retries_admitted, d.retry_spent, "driver and hub must agree on retries");
    // The amplification bound: the budget starts empty and earns
    // `retry_frac` per fresh admit, so lifetime spend can never exceed
    // that fraction of fresh traffic.
    assert!(d.retry_spent > 0, "an earned budget must admit some retries");
    assert!(
        (d.retry_spent as f64) <= RETRY_FRAC * d.admitted as f64 + 1.0,
        "retry budget must clamp the storm: spent {} vs {} fresh admits",
        d.retry_spent,
        d.admitted
    );
    // The clamp is doing real work: the scripted storm offered far more
    // retry traffic than the budget let through.
    assert!(
        l.retries_submitted > 2 * l.retries_admitted,
        "storm too small to demonstrate clamping: {} submitted, {} admitted",
        l.retries_submitted,
        l.retries_admitted
    );
    stack.shutdown();
}
