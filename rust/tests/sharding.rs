//! Cross-device sharding acceptance suite: the shard router serving
//! through partition peers under a *time-varying link trace*, with every
//! degrade/re-admit decision driven by `TelemetrySnapshot` data only —
//! plus the fully closed control plane (`tick_with_telemetry` actuating
//! `set_shards`) degrading a drifting link, and **segment streaming**:
//! mid-chain splits (local prefix, frontier across the link, remote
//! tail) that beat both local-only and full-remote serving when the
//! link affords a frontier tensor but not whole-input shipping, and
//! that retreat to local-only when bandwidth collapses. Mock executors
//! + simulated peers throughout: no built artifacts, no network.

use std::time::Duration;

use anyhow::Result;
use crowdhmtware::coordinator::{
    BatcherConfig, Executor, PoolConfig, ServingPool, ShardRouter, ShardRouterConfig, Submission,
    REMOTE_WORKER_BASE,
};
use crowdhmtware::device::{device, ResourceMonitor};
use crowdhmtware::models::{backbone, BackboneConfig};
use crowdhmtware::optimizer::{AdaptLoop, Budgets, Candidate, Decision};
use crowdhmtware::partition::{OffloadPlan, Placement, SharedLink};
use crowdhmtware::runtime::SegmentedExec;

const CLASSES: usize = 4;
/// 16 KB inputs: big enough that link bandwidth — not RTT — dominates the
/// transfer term, so a 10× bandwidth drop is a ~10× transfer-cost jump.
const ELEMS: usize = 4096;

/// Deterministic fake model: class = argmax over the first CLASSES input
/// values; each batch costs a fixed wall-clock delay.
struct MockExec {
    delay: Duration,
}

impl Executor for MockExec {
    fn batch_sizes(&self, _variant: &str) -> Vec<usize> {
        vec![1]
    }

    fn num_classes(&self) -> usize {
        CLASSES
    }

    fn input_elems(&self) -> usize {
        ELEMS
    }

    fn run(&mut self, _variant: &str, batch: usize, input: &[f32]) -> Result<Vec<f32>> {
        std::thread::sleep(self.delay);
        let mut out = vec![0.0f32; batch * CLASSES];
        for b in 0..batch {
            let row = &input[b * ELEMS..b * ELEMS + CLASSES];
            let total: f32 = row.iter().map(|x| x.exp()).sum();
            for (k, &x) in row.iter().enumerate() {
                out[b * CLASSES + k] = x.exp() / total;
            }
        }
        Ok(out)
    }
}

fn input_for(class: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; ELEMS];
    v[class % CLASSES] = 4.0;
    v
}

fn local_pool(workers: usize, delay: Duration, variant: &str) -> ServingPool {
    ServingPool::spawn(
        move |_| Box::new(MockExec { delay }) as Box<dyn Executor>,
        variant,
        PoolConfig {
            workers,
            queue_capacity: 256,
            batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_micros(100) },
            ..PoolConfig::default()
        },
    )
}

/// One adaptation-style tick: submit a burst through the router, wait for
/// every response, snapshot the hub, reconcile shard admission from that
/// snapshot alone. Returns (remote-routed delta, probe delta, local
/// delta) for the burst.
fn tick(router: &ShardRouter, burst: usize) -> (usize, usize, usize) {
    let before = router.shard_stats();
    let rxs: Vec<_> = (0..burst)
        .map(|i| router.submit_with(Submission::new(input_for(i))).expect("admitted"))
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let r = rx.recv_timeout(Duration::from_secs(30)).expect("response");
        assert_eq!(r.pred, i % CLASSES, "wrong prediction (local/remote must agree)");
    }
    let tel = router.telemetry_snapshot();
    router.maintain(&tel);
    let after = router.shard_stats();
    (
        after.routed_remote() - before.routed_remote(),
        after.peers.iter().map(|p| p.probes).sum::<usize>()
            - before.peers.iter().map(|p| p.probes).sum::<usize>(),
        after.routed_local - before.routed_local,
    )
}

/// The acceptance scenario: under a degrading link trace (bandwidth drops
/// 10×) the router shifts traffic back to local workers within a few
/// ticks — deciding from `TelemetrySnapshot` data only — and re-offloads
/// after the link recovers.
#[test]
fn degrading_link_sheds_to_local_and_reoffloads_on_recovery() {
    const BURST: usize = 8;
    // Healthy peer round trip ≈ 1 ms exec + ~5.3 ms transfer (16 KB at
    // 40 Mbit/s + 2 ms RTT) ≈ 6.3 ms; local ≈ 8 ms/request. After the 10×
    // bandwidth drop the peer costs ≳ 35 ms — far past the 15 ms degrade
    // budget; after recovery it is well under the 10 ms re-admit bar.
    let link = SharedLink::new(40.0, 2.0);
    let router = ShardRouter::new(
        local_pool(2, Duration::from_millis(8), "v"),
        ShardRouterConfig {
            peer_capacity: 3,
            degrade_latency_s: 0.015,
            readmit_latency_s: 0.010,
            probe_every: 2,
            local_prior_s: 0.008,
            ..ShardRouterConfig::default()
        },
    );
    router.add_simulated_peer(
        "edge-peer",
        || Box::new(MockExec { delay: Duration::from_millis(1) }) as Box<dyn Executor>,
        link.clone(),
        0.006, // plan-predicted remote latency: preferred over local
    );

    // ── Phase 1: healthy link — the plan-preferred peer takes traffic.
    let mut remote_healthy = 0;
    for _ in 0..3 {
        let (r, _, _) = tick(&router, BURST);
        remote_healthy += r;
    }
    assert_eq!(router.admitted_peers(), 1, "healthy peer must stay admitted");
    assert!(
        remote_healthy >= 4,
        "plan-preferred peer must carry real traffic when healthy, got {remote_healthy}/24"
    );

    // ── Phase 2: the link degrades 10×. Measured round trips breach the
    // budget and the router evicts the peer within a few ticks.
    link.scale_bandwidth(0.1);
    let mut degraded_at = None;
    for t in 1..=5 {
        tick(&router, BURST);
        if router.admitted_peers() == 0 {
            degraded_at = Some(t);
            break;
        }
    }
    let t = degraded_at.expect("router never degraded the 10×-slower link");
    assert!(t <= 5, "degradation detected too slowly: {t} ticks");
    assert!(router.shard_stats().degraded_events >= 1);

    // Post-degrade, remote traffic is probes only — everything else runs
    // on the local workers.
    for _ in 0..2 {
        let (remote, probes, local) = tick(&router, BURST);
        assert_eq!(remote, probes, "degraded peer must receive probe traffic only");
        assert_eq!(local + remote, BURST);
        assert!(local >= BURST - probes, "traffic must shift to local workers");
    }

    // ── Phase 3: the link recovers. Probes observe it; the EWMA falls
    // under the re-admit bar and traffic flows remote again.
    link.scale_bandwidth(10.0);
    let mut readmitted_at = None;
    for t in 1..=8 {
        tick(&router, BURST);
        if router.admitted_peers() == 1 {
            readmitted_at = Some(t);
            break;
        }
    }
    let t = readmitted_at.expect("router never re-admitted the recovered link");
    assert!(t <= 8, "re-admission took too long: {t} ticks");
    assert!(router.shard_stats().readmitted_events >= 1);

    let mut remote_recovered = 0;
    let mut probes_recovered = 0;
    for _ in 0..3 {
        let (r, p, _) = tick(&router, BURST);
        remote_recovered += r;
        probes_recovered += p;
    }
    assert!(
        remote_recovered > probes_recovered,
        "recovered peer must carry non-probe traffic again: {remote_recovered} routed, {probes_recovered} probes"
    );

    // Lifetime accounting holds across the whole trace: every submission
    // was served exactly once, by a worker or by the peer link.
    let tel = router.telemetry_snapshot();
    let stats = router.shutdown();
    assert_eq!(stats.served(), tel.served);
    assert_eq!(stats.failed(), 0);
}

/// The closed control plane drives the same reconciliation: peers are
/// `set_shards`-actuated by `AdaptLoop::tick_with_telemetry`, so a
/// drifting link degrades without anyone calling the router directly.
#[test]
fn control_plane_degrades_drifting_link_via_set_shards() {
    let g = backbone(&BackboneConfig::default());
    let snap = ResourceMonitor::new(device("jetson-nx").unwrap()).idle_snapshot();
    let mut l = AdaptLoop::new(
        g,
        80.0,
        vec![Candidate::baseline()],
        Budgets { latency_s: f64::INFINITY, memory_bytes: f64::INFINITY },
    );

    // A peer whose real round trip (~6 ms transfer) sits far above the
    // 2 ms degrade budget, but whose optimistic plan prior attracts
    // traffic first — the classic misprediction telemetry must correct.
    let router = ShardRouter::new(
        local_pool(1, Duration::from_micros(500), "cold-start"),
        ShardRouterConfig {
            degrade_latency_s: 0.002,
            readmit_latency_s: 0.001,
            probe_every: 0, // no probes: once degraded, stays local (deterministic)
            local_prior_s: 0.050,
            ..ShardRouterConfig::default()
        },
    );
    router.add_simulated_peer(
        "overloaded-peer",
        || Box::new(MockExec { delay: Duration::from_millis(1) }) as Box<dyn Executor>,
        SharedLink::new(40.0, 2.0),
        0.0005,
    );
    assert_eq!(router.admitted_peers(), 1);

    // Tick 1: first decision switches the variant; the broadcast reaches
    // pool workers and the peer through the router's actuate.
    let chosen = match l.tick_with_telemetry(&snap, &router.telemetry_snapshot(), &router) {
        Decision::Switch(e) => e.candidate.spec.detailed_label(),
        d => panic!("expected Switch, got {d:?}"),
    };
    assert_eq!(router.admitted_peers(), 1, "no measurements yet: peer stays admitted");

    // Traffic flows; the optimistic prior routes it to the peer, whose
    // measured round trips pile into the hub EWMA.
    let rxs: Vec<_> = (0..6)
        .map(|i| router.submit_with(Submission::new(input_for(i))).expect("admitted"))
        .collect();
    let mut remote = 0;
    for rx in rxs {
        let r = rx.recv_timeout(Duration::from_secs(20)).expect("response");
        assert_eq!(&*r.variant, chosen, "actuated variant must reach peers and workers");
        if r.worker >= REMOTE_WORKER_BASE {
            remote += 1;
        }
    }
    assert!(remote > 0, "optimistic plan prior must route traffic to the peer first");

    // Tick 2: the control plane's set_shards arm reads the measured drift
    // from the same snapshot the calibrator uses and evicts the peer.
    l.tick_with_telemetry(&snap, &router.telemetry_snapshot(), &router);
    assert_eq!(router.admitted_peers(), 0, "set_shards must degrade the drifting link");

    // Subsequent traffic is local-only (probing disabled).
    let rxs: Vec<_> = (0..4)
        .map(|i| router.submit_with(Submission::new(input_for(i))).expect("admitted"))
        .collect();
    for rx in rxs {
        let r = rx.recv_timeout(Duration::from_secs(20)).expect("response");
        assert!(r.worker < REMOTE_WORKER_BASE, "degraded peer must not serve");
    }
    router.shutdown();
}

// ── segment streaming ─────────────────────────────────────────────────

/// Two-segment chain over the 16 KB input: a cheap head, then a heavy
/// tail, with a 64-element (256 B) frontier at the cut — the shape that
/// makes a mid-chain split worthwhile on a link too slow for the input.
fn seg_chain(head: Duration, tail: Duration) -> SegmentedExec {
    SegmentedExec::new(CLASSES, vec![ELEMS, 64, CLASSES], vec![head, tail])
}

fn split_router(link: SharedLink) -> ShardRouter {
    // Local: 1 ms head + 7 ms tail = 8 ms/request on 2 workers.
    let pool = ServingPool::spawn(
        move |_| {
            Box::new(seg_chain(Duration::from_millis(1), Duration::from_millis(7)))
                as Box<dyn Executor>
        },
        "v",
        PoolConfig {
            workers: 2,
            queue_capacity: 256,
            batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_micros(100) },
            ..PoolConfig::default()
        },
    );
    let router = ShardRouter::new(
        pool,
        ShardRouterConfig {
            peer_capacity: 8,
            degrade_latency_s: 0.020,
            readmit_latency_s: 0.012,
            probe_every: 4,
            local_prior_s: 0.008,
            ..ShardRouterConfig::default()
        },
    );
    // Peer runs both segments in 1 ms each; the plan prior is infinite
    // until an offload plan prices a route.
    router.add_simulated_peer(
        "edge-split",
        || {
            Box::new(seg_chain(Duration::from_millis(1), Duration::from_millis(1)))
                as Box<dyn Executor>
        },
        link,
        f64::INFINITY,
    );
    router
}

/// The planner's mid-chain output for the chain above: segment 0 local,
/// segment 1 on the peer, split round trip predicted at 4 ms.
fn mid_chain_plan() -> OffloadPlan {
    OffloadPlan {
        placements: vec![
            Placement { device: "local-device".into(), segments: vec![0] },
            Placement { device: "edge-split".into(), segments: vec![1] },
        ],
        latency_s: 0.004,
        energy_j: 0.1,
        local_memory_bytes: 1.0,
        transfer_bytes: 256,
    }
}

/// Serial burst: one request at a time, so measured round trips carry no
/// queueing noise and route comparisons stay deterministic. Returns how
/// many responses came from the peer link.
fn serial_burst(router: &ShardRouter, n: usize) -> usize {
    let mut remote = 0usize;
    for i in 0..n {
        let rx = router.submit_with(Submission::new(input_for(i))).expect("admitted");
        let r = rx.recv_timeout(Duration::from_secs(30)).expect("response");
        assert_eq!(r.pred, i % CLASSES, "split, remote, and local serving must agree");
        if r.worker >= REMOTE_WORKER_BASE {
            remote += 1;
        }
    }
    router.maintain(&router.telemetry_snapshot());
    remote
}

/// Wait for the peer thread to publish its transport's segment
/// capability (the seeded cut is unroutable until it does).
fn wait_split_routable(router: &ShardRouter) {
    for _ in 0..500 {
        if router.admitted_splits() == 1 {
            return;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    panic!("split route never became routable");
}

/// The acceptance scenario (ISSUE 5): on a link fast enough for the
/// frontier tensor (256 B ≈ 0.5 ms) but too slow for whole-input
/// shipping (16 KB ≈ 33 ms), the router serves `split@1` requests whose
/// measured latency beats BOTH local-only (8 ms) and full-remote
/// (~35 ms), with nonzero `split_served` counters — the offload plan's
/// per-segment placement surviving into the serving path.
#[test]
fn mid_chain_split_beats_local_and_full_remote() {
    // 4 Mbit/s, 1 ms RTT: 500 KB/s → input 33 ms, frontier 0.5 ms.
    let router = split_router(SharedLink::new(4.0, 1.0));

    // ── Phase 1: no plan yet. Traffic runs local (the peer's only
    // exposure is probe turns on its unpriced full-remote route, which
    // measure the ~35 ms round trip).
    let remote = serial_burst(&router, 16);
    let tel = router.telemetry_snapshot();
    let local_ewma: Vec<f64> = tel
        .per_worker
        .iter()
        .filter(|v| !v.remote && v.ewma_s > 0.0)
        .map(|v| v.ewma_s)
        .collect();
    assert!(!local_ewma.is_empty(), "local workers must be measured in phase 1");
    let local_s = local_ewma.iter().sum::<f64>() / local_ewma.len() as f64;
    assert!(local_s > 0.004, "local serving costs ~8 ms, measured {local_s}");
    let stats = router.shard_stats();
    assert_eq!(
        remote, stats.peers[0].probes,
        "an unpriced peer gets probe traffic only"
    );

    // ── Phase 2: the planner's mid-chain cut actuates a split route.
    router.apply_plan(&mid_chain_plan(), 0.008);
    wait_split_routable(&router);
    serial_burst(&router, 32);

    let stats = router.shard_stats();
    let peer = &stats.peers[0];
    assert!(peer.split_served > 0, "split_served must be nonzero");
    assert!(
        peer.split_routed > peer.split_probes,
        "the split must win scored dispatch, not just probe turns"
    );
    assert_eq!(peer.cut, 1);

    // The measured comparison the split exists for: frontier streaming
    // beats both alternatives.
    let tel = router.telemetry_snapshot();
    let pv = tel.per_worker.iter().find(|v| v.remote).expect("peer slot");
    assert!(pv.split_ewma_s > 0.0, "split lane must be measured");
    assert!(
        pv.split_ewma_s < local_s,
        "split ({:.4}s) must beat local-only ({local_s:.4}s)",
        pv.split_ewma_s
    );
    assert!(pv.ewma_s > 0.020, "probed full-remote round trips ship the whole input");
    assert!(
        pv.split_ewma_s < pv.ewma_s,
        "split ({:.4}s) must beat full-remote ({:.4}s)",
        pv.split_ewma_s,
        pv.ewma_s
    );
    assert_eq!(tel.split_served, peer.split_served, "hub total mirrors the link counter");

    // Full accounting across the whole run.
    let stats = router.shutdown();
    assert_eq!(stats.served(), 48);
    assert_eq!(stats.failed(), 0);
}

/// A bandwidth collapse makes even the frontier shipment breach the
/// budget: the router retreats `split@k → local-only` from telemetry
/// alone, keeps the cut probed while degraded, and re-admits it after
/// the link recovers.
#[test]
fn bandwidth_drop_retreats_split_to_local_and_readmits() {
    let link = SharedLink::new(4.0, 1.0);
    let router = split_router(link.clone());
    router.apply_plan(&mid_chain_plan(), 0.008);
    wait_split_routable(&router);

    // Healthy: the split carries real (non-probe) traffic.
    serial_burst(&router, 16);
    let healthy = router.shard_stats();
    assert!(healthy.peers[0].split_routed > healthy.peers[0].split_probes);
    assert_eq!(router.admitted_splits(), 1);

    // ── The link collapses 100×: the 256 B frontier now costs ~51 ms,
    // far past the 20 ms degrade budget. The router must retreat the
    // split within a few reconciliations.
    link.scale_bandwidth(0.01);
    let mut retreated_at = None;
    for t in 1..=6 {
        serial_burst(&router, 8);
        if router.admitted_splits() == 0 {
            retreated_at = Some(t);
            break;
        }
    }
    retreated_at.expect("router never retreated the collapsed split to local-only");
    assert!(router.shard_stats().split_degraded_events >= 1);
    let tel = router.telemetry_snapshot();
    assert!(tel.split_degraded >= 1, "the degrade is charged to the link's hub slot");

    // While degraded, split traffic is probes only.
    let before = router.shard_stats();
    serial_burst(&router, 8);
    let after = router.shard_stats();
    let split_delta = after.peers[0].split_routed - before.peers[0].split_routed;
    let probe_delta = after.peers[0].split_probes - before.peers[0].split_probes;
    assert_eq!(split_delta, probe_delta, "a degraded split receives probe traffic only");

    // ── Recovery: probes observe the restored link; the split EWMA
    // decays under the re-admit bar and the route rejoins.
    link.scale_bandwidth(100.0);
    let mut readmitted_at = None;
    for t in 1..=15 {
        serial_burst(&router, 8);
        if router.admitted_splits() == 1 {
            readmitted_at = Some(t);
            break;
        }
    }
    readmitted_at.expect("router never re-admitted the recovered split");
    assert!(router.shard_stats().split_readmitted_events >= 1);

    // Re-admitted: non-probe split traffic resumes.
    let before = router.shard_stats();
    serial_burst(&router, 16);
    let after = router.shard_stats();
    let split_delta = after.peers[0].split_routed - before.peers[0].split_routed;
    let probe_delta = after.peers[0].split_probes - before.peers[0].split_probes;
    assert!(
        split_delta > probe_delta,
        "re-admitted split must carry scored traffic again ({split_delta} vs {probe_delta})"
    );

    let tel = router.telemetry_snapshot();
    let stats = router.shutdown();
    assert_eq!(stats.served(), tel.served);
    assert_eq!(stats.failed(), 0);
}
