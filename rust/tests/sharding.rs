//! Cross-device sharding acceptance suite: the shard router serving
//! through partition peers under a *time-varying link trace*, with every
//! degrade/re-admit decision driven by `TelemetrySnapshot` data only —
//! plus the fully closed control plane (`tick_with_telemetry` actuating
//! `set_shards`) degrading a drifting link. Mock executors + simulated
//! peers throughout: no built artifacts, no network.

use std::time::Duration;

use anyhow::Result;
use crowdhmtware::coordinator::{
    BatcherConfig, Executor, PoolConfig, ServingPool, ShardRouter, ShardRouterConfig,
    REMOTE_WORKER_BASE,
};
use crowdhmtware::device::{device, ResourceMonitor};
use crowdhmtware::models::{backbone, BackboneConfig};
use crowdhmtware::optimizer::{AdaptLoop, Budgets, Candidate, Decision};
use crowdhmtware::partition::SharedLink;

const CLASSES: usize = 4;
/// 16 KB inputs: big enough that link bandwidth — not RTT — dominates the
/// transfer term, so a 10× bandwidth drop is a ~10× transfer-cost jump.
const ELEMS: usize = 4096;

/// Deterministic fake model: class = argmax over the first CLASSES input
/// values; each batch costs a fixed wall-clock delay.
struct MockExec {
    delay: Duration,
}

impl Executor for MockExec {
    fn batch_sizes(&self, _variant: &str) -> Vec<usize> {
        vec![1]
    }

    fn num_classes(&self) -> usize {
        CLASSES
    }

    fn input_elems(&self) -> usize {
        ELEMS
    }

    fn run(&mut self, _variant: &str, batch: usize, input: &[f32]) -> Result<Vec<f32>> {
        std::thread::sleep(self.delay);
        let mut out = vec![0.0f32; batch * CLASSES];
        for b in 0..batch {
            let row = &input[b * ELEMS..b * ELEMS + CLASSES];
            let total: f32 = row.iter().map(|x| x.exp()).sum();
            for (k, &x) in row.iter().enumerate() {
                out[b * CLASSES + k] = x.exp() / total;
            }
        }
        Ok(out)
    }
}

fn input_for(class: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; ELEMS];
    v[class % CLASSES] = 4.0;
    v
}

fn local_pool(workers: usize, delay: Duration, variant: &str) -> ServingPool {
    ServingPool::spawn(
        move |_| Box::new(MockExec { delay }) as Box<dyn Executor>,
        variant,
        PoolConfig {
            workers,
            queue_capacity: 256,
            batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_micros(100) },
            ..PoolConfig::default()
        },
    )
}

/// One adaptation-style tick: submit a burst through the router, wait for
/// every response, snapshot the hub, reconcile shard admission from that
/// snapshot alone. Returns (remote-routed delta, probe delta, local
/// delta) for the burst.
fn tick(router: &ShardRouter, burst: usize) -> (usize, usize, usize) {
    let before = router.shard_stats();
    let rxs: Vec<_> = (0..burst).map(|i| router.submit(input_for(i)).expect("admitted")).collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let r = rx.recv_timeout(Duration::from_secs(30)).expect("response");
        assert_eq!(r.pred, i % CLASSES, "wrong prediction (local/remote must agree)");
    }
    let tel = router.telemetry_snapshot();
    router.maintain(&tel);
    let after = router.shard_stats();
    (
        after.routed_remote() - before.routed_remote(),
        after.peers.iter().map(|p| p.probes).sum::<usize>()
            - before.peers.iter().map(|p| p.probes).sum::<usize>(),
        after.routed_local - before.routed_local,
    )
}

/// The acceptance scenario: under a degrading link trace (bandwidth drops
/// 10×) the router shifts traffic back to local workers within a few
/// ticks — deciding from `TelemetrySnapshot` data only — and re-offloads
/// after the link recovers.
#[test]
fn degrading_link_sheds_to_local_and_reoffloads_on_recovery() {
    const BURST: usize = 8;
    // Healthy peer round trip ≈ 1 ms exec + ~5.3 ms transfer (16 KB at
    // 40 Mbit/s + 2 ms RTT) ≈ 6.3 ms; local ≈ 8 ms/request. After the 10×
    // bandwidth drop the peer costs ≳ 35 ms — far past the 15 ms degrade
    // budget; after recovery it is well under the 10 ms re-admit bar.
    let link = SharedLink::new(40.0, 2.0);
    let router = ShardRouter::new(
        local_pool(2, Duration::from_millis(8), "v"),
        ShardRouterConfig {
            peer_capacity: 3,
            degrade_latency_s: 0.015,
            readmit_latency_s: 0.010,
            probe_every: 2,
            local_prior_s: 0.008,
        },
    );
    router.add_simulated_peer(
        "edge-peer",
        || Box::new(MockExec { delay: Duration::from_millis(1) }) as Box<dyn Executor>,
        link.clone(),
        0.006, // plan-predicted remote latency: preferred over local
    );

    // ── Phase 1: healthy link — the plan-preferred peer takes traffic.
    let mut remote_healthy = 0;
    for _ in 0..3 {
        let (r, _, _) = tick(&router, BURST);
        remote_healthy += r;
    }
    assert_eq!(router.admitted_peers(), 1, "healthy peer must stay admitted");
    assert!(
        remote_healthy >= 4,
        "plan-preferred peer must carry real traffic when healthy, got {remote_healthy}/24"
    );

    // ── Phase 2: the link degrades 10×. Measured round trips breach the
    // budget and the router evicts the peer within a few ticks.
    link.scale_bandwidth(0.1);
    let mut degraded_at = None;
    for t in 1..=5 {
        tick(&router, BURST);
        if router.admitted_peers() == 0 {
            degraded_at = Some(t);
            break;
        }
    }
    let t = degraded_at.expect("router never degraded the 10×-slower link");
    assert!(t <= 5, "degradation detected too slowly: {t} ticks");
    assert!(router.shard_stats().degraded_events >= 1);

    // Post-degrade, remote traffic is probes only — everything else runs
    // on the local workers.
    for _ in 0..2 {
        let (remote, probes, local) = tick(&router, BURST);
        assert_eq!(remote, probes, "degraded peer must receive probe traffic only");
        assert_eq!(local + remote, BURST);
        assert!(local >= BURST - probes, "traffic must shift to local workers");
    }

    // ── Phase 3: the link recovers. Probes observe it; the EWMA falls
    // under the re-admit bar and traffic flows remote again.
    link.scale_bandwidth(10.0);
    let mut readmitted_at = None;
    for t in 1..=8 {
        tick(&router, BURST);
        if router.admitted_peers() == 1 {
            readmitted_at = Some(t);
            break;
        }
    }
    let t = readmitted_at.expect("router never re-admitted the recovered link");
    assert!(t <= 8, "re-admission took too long: {t} ticks");
    assert!(router.shard_stats().readmitted_events >= 1);

    let mut remote_recovered = 0;
    let mut probes_recovered = 0;
    for _ in 0..3 {
        let (r, p, _) = tick(&router, BURST);
        remote_recovered += r;
        probes_recovered += p;
    }
    assert!(
        remote_recovered > probes_recovered,
        "recovered peer must carry non-probe traffic again: {remote_recovered} routed, {probes_recovered} probes"
    );

    // Lifetime accounting holds across the whole trace: every submission
    // was served exactly once, by a worker or by the peer link.
    let tel = router.telemetry_snapshot();
    let stats = router.shutdown();
    assert_eq!(stats.served(), tel.served);
    assert_eq!(stats.failed(), 0);
}

/// The closed control plane drives the same reconciliation: peers are
/// `set_shards`-actuated by `AdaptLoop::tick_with_telemetry`, so a
/// drifting link degrades without anyone calling the router directly.
#[test]
fn control_plane_degrades_drifting_link_via_set_shards() {
    let g = backbone(&BackboneConfig::default());
    let snap = ResourceMonitor::new(device("jetson-nx").unwrap()).idle_snapshot();
    let mut l = AdaptLoop::new(
        g,
        80.0,
        vec![Candidate::baseline()],
        Budgets { latency_s: f64::INFINITY, memory_bytes: f64::INFINITY },
    );

    // A peer whose real round trip (~6 ms transfer) sits far above the
    // 2 ms degrade budget, but whose optimistic plan prior attracts
    // traffic first — the classic misprediction telemetry must correct.
    let router = ShardRouter::new(
        local_pool(1, Duration::from_micros(500), "cold-start"),
        ShardRouterConfig {
            degrade_latency_s: 0.002,
            readmit_latency_s: 0.001,
            probe_every: 0, // no probes: once degraded, stays local (deterministic)
            local_prior_s: 0.050,
            ..ShardRouterConfig::default()
        },
    );
    router.add_simulated_peer(
        "overloaded-peer",
        || Box::new(MockExec { delay: Duration::from_millis(1) }) as Box<dyn Executor>,
        SharedLink::new(40.0, 2.0),
        0.0005,
    );
    assert_eq!(router.admitted_peers(), 1);

    // Tick 1: first decision switches the variant; the broadcast reaches
    // pool workers and the peer through the router's actuate.
    let chosen = match l.tick_with_telemetry(&snap, &router.telemetry_snapshot(), &router) {
        Decision::Switch(e) => e.candidate.spec.detailed_label(),
        d => panic!("expected Switch, got {d:?}"),
    };
    assert_eq!(router.admitted_peers(), 1, "no measurements yet: peer stays admitted");

    // Traffic flows; the optimistic prior routes it to the peer, whose
    // measured round trips pile into the hub EWMA.
    let rxs: Vec<_> = (0..6).map(|i| router.submit(input_for(i)).expect("admitted")).collect();
    let mut remote = 0;
    for rx in rxs {
        let r = rx.recv_timeout(Duration::from_secs(20)).expect("response");
        assert_eq!(r.variant, chosen, "actuated variant must reach peers and workers");
        if r.worker >= REMOTE_WORKER_BASE {
            remote += 1;
        }
    }
    assert!(remote > 0, "optimistic plan prior must route traffic to the peer first");

    // Tick 2: the control plane's set_shards arm reads the measured drift
    // from the same snapshot the calibrator uses and evicts the peer.
    l.tick_with_telemetry(&snap, &router.telemetry_snapshot(), &router);
    assert_eq!(router.admitted_peers(), 0, "set_shards must degrade the drifting link");

    // Subsequent traffic is local-only (probing disabled).
    let rxs: Vec<_> = (0..4).map(|i| router.submit(input_for(i)).expect("admitted")).collect();
    for rx in rxs {
        let r = rx.recv_timeout(Duration::from_secs(20)).expect("response");
        assert!(r.worker < REMOTE_WORKER_BASE, "degraded peer must not serve");
    }
    router.shutdown();
}
