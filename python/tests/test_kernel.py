"""L1 correctness: the Pallas fused-matmul kernel vs the pure-jnp oracle,
swept over shapes/dtypes with hypothesis — the core correctness signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.matmul import factorized_matmul, matmul_fused, vmem_bytes
from compile.kernels.ref import factorized_matmul_ref, matmul_fused_ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape).astype(dtype)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 70),
    act=st.sampled_from(["none", "relu", "tanh"]),
    bias=st.booleans(),
)
def test_matmul_fused_matches_ref(m, k, n, act, bias):
    x = rand(m * 7 + 1, (m, k), jnp.float32)
    w = rand(k * 13 + 2, (k, n), jnp.float32)
    b = rand(n * 17 + 3, (n,), jnp.float32) if bias else None
    got = matmul_fused(x, w, b, act)
    ref = matmul_fused_ref(x, w, b, act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(2, 40),
    k=st.integers(4, 60),
    n=st.integers(4, 60),
    r=st.integers(1, 8),
)
def test_factorized_matmul_matches_ref(m, k, n, r):
    x = rand(1, (m, k), jnp.float32)
    u = rand(2, (k, r), jnp.float32)
    v = rand(3, (r, n), jnp.float32)
    b = rand(4, (n,), jnp.float32)
    got = factorized_matmul(x, u, v, b, "relu")
    ref = factorized_matmul_ref(x, u, v, b, "relu")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", [(128, 128, 128), (256, 128, 384), (1, 1, 1), (8, 1024, 8)])
def test_tile_aligned_and_degenerate_shapes(shape):
    m, k, n = shape
    x = rand(10, (m, k), jnp.float32)
    w = rand(11, (k, n), jnp.float32)
    got = matmul_fused(x, w, None, "none")
    ref = matmul_fused_ref(x, w, None, "none")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_relu_epilogue_clamps():
    x = -jnp.ones((4, 4), jnp.float32)
    w = jnp.eye(4, dtype=jnp.float32)
    out = matmul_fused(x, w, None, "relu")
    assert float(jnp.max(out)) == 0.0


def test_custom_tiles_agree():
    x = rand(20, (50, 33), jnp.float32)
    w = rand(21, (33, 17), jnp.float32)
    a = matmul_fused(x, w, None, "none", bm=16, bn=16, bk=16)
    b = matmul_fused(x, w, None, "none", bm=128, bn=128, bk=128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_vmem_budget_under_tpu_limit():
    # Default tiles must fit the ~16 MiB/core VMEM budget with headroom.
    assert vmem_bytes() < 4 * 1024 * 1024


def test_lowers_to_hlo_text():
    # The interpret-mode kernel must lower to plain HLO (no custom calls)
    # so the Rust CPU PJRT client can execute it.
    from compile.aot import to_hlo_text

    fn = lambda x, w: matmul_fused(x, w, None, "relu")
    spec = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    text = to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert "ENTRY" in text
    assert "custom-call" not in text.lower() or "mosaic" not in text.lower()
