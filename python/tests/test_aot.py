"""AOT pipeline contract tests (no training — random weights): HLO text
properties the Rust loader depends on, manifest-relevant cost formulas,
and the kernel's VMEM/structure invariants."""

import functools

import jax
import jax.numpy as jnp
import pytest

from compile.aot import mac_count, param_count, to_hlo_text
from compile.model import VariantConfig, forward, init_params, svd_factorize

CFG = VariantConfig()


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), CFG)


def lower_variant(params, batch, **kwargs):
    fn = functools.partial(forward, params, cfg=CFG, use_pallas=True, **kwargs)
    spec = jax.ShapeDtypeStruct((batch, CFG.input_hw, CFG.input_hw, CFG.in_channels), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(spec))


def test_hlo_has_entry_and_single_param(params):
    text = lower_variant(params, 1)
    assert "ENTRY" in text
    # Exactly one runtime parameter (the input); weights are constants.
    assert text.count("parameter(0)") >= 1
    assert "parameter(1)" not in text.split("ENTRY")[-1]


def test_hlo_constants_not_elided(params):
    # The regression that silently zeroed all weights: `constant({...})`.
    text = lower_variant(params, 1)
    assert "constant({...})" not in text, "large constants were elided"


def test_hlo_no_mosaic_custom_calls(params):
    # interpret=True must lower to plain HLO the CPU PJRT client can run.
    text = lower_variant(params, 8)
    assert "mosaic" not in text.lower()


def test_hlo_batch_in_entry_layout(params):
    t1 = lower_variant(params, 1)
    t8 = lower_variant(params, 8)
    assert "f32[1,16,16,3]" in t1
    assert "f32[8,16,16,3]" in t8


def test_all_variant_kinds_lower(params):
    svd = svd_factorize(params, CFG, 0.5)
    for kwargs in [{}, {"width_mult": 0.5}, {"exit_idx": 0}, {"svd": svd}]:
        text = lower_variant(params, 1, **kwargs)
        assert "ENTRY" in text


def test_cost_formulas_monotone():
    full_p = param_count(CFG, 1.0, None, 1.0)
    assert param_count(CFG, 1.0, 1, 1.0) < full_p  # earlier exit
    assert param_count(CFG, 0.5, None, 1.0) < full_p  # narrower
    assert param_count(CFG, 1.0, None, 0.5) < full_p  # low-rank
    full_m = mac_count(CFG, 1.0, None, 1.0)
    assert mac_count(CFG, 0.5, None, 1.0) < full_m // 2
