"""L2 correctness: backbone shapes, variant semantics, pallas≡jnp path
agreement, ensemble-training behaviour, and the AOT lowering contract."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    VariantConfig,
    accuracy,
    class_templates,
    drifted,
    ensemble_loss,
    forward,
    im2col,
    init_params,
    make_dataset,
    maxpool2,
    svd_factorize,
    train,
)

CFG = VariantConfig()


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def trained():
    # Short but meaningful training for behavioural tests.
    p, losses = train(jax.random.PRNGKey(0), CFG, steps=250)
    return p, losses


def test_im2col_shape_and_content():
    x = jnp.arange(2 * 4 * 4 * 3, dtype=jnp.float32).reshape(2, 4, 4, 3)
    p = im2col(x, 1)
    assert p.shape == (2, 4, 4, 27)
    # Center position (di=dj=1) of patch at (1,1) equals x[:,1,1,:].
    center = p[:, 1, 1, 4 * 3 : 5 * 3]
    np.testing.assert_allclose(np.asarray(center), np.asarray(x[:, 1, 1, :]))


def test_im2col_stride2_downsamples():
    x = jnp.ones((1, 8, 8, 2))
    p = im2col(x, 2)
    assert p.shape == (1, 4, 4, 18)


def test_maxpool_halves():
    x = jnp.arange(16, dtype=jnp.float32).reshape(1, 4, 4, 1)
    y = maxpool2(x)
    assert y.shape == (1, 2, 2, 1)
    assert float(y[0, 0, 0, 0]) == 5.0  # max of [[0,1],[4,5]]


def test_forward_shapes_all_exits(params):
    x = jnp.zeros((4, CFG.input_hw, CFG.input_hw, CFG.in_channels))
    for e in range(len(CFG.widths)):
        probs = forward(params, x, CFG, exit_idx=e)
        assert probs.shape == (4, CFG.num_classes)
        np.testing.assert_allclose(np.asarray(jnp.sum(probs, -1)), 1.0, rtol=1e-5)


def test_pallas_path_matches_jnp_path(params):
    x = jax.random.normal(jax.random.PRNGKey(3), (8, CFG.input_hw, CFG.input_hw, CFG.in_channels))
    for kwargs in [
        {},
        {"width_mult": 0.5},
        {"exit_idx": 0},
        {"svd": svd_factorize(params, CFG, 0.5)},
    ]:
        a = forward(params, x, CFG, use_pallas=False, **kwargs)
        b = forward(params, x, CFG, use_pallas=True, **kwargs)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_width_mult_uses_weight_prefix(params):
    # Half-width output must depend only on the first half channels:
    # zeroing the second half of every conv weight must not change it.
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 16, 16, 3))
    half = forward(params, x, CFG, width_mult=0.5)
    mutated = dict(params)
    for k, v in params.items():
        if k.endswith("_w") and k.startswith(("stem", "s")):
            arr = np.asarray(v).copy()
            arr[:, arr.shape[1] // 2 :] = 99.0
            mutated[k] = jnp.asarray(arr)
    half2 = forward(mutated, x, CFG, width_mult=0.5)
    np.testing.assert_allclose(np.asarray(half), np.asarray(half2), rtol=1e-5)


def test_svd_full_rank_is_exact(params):
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 16, 16, 3))
    svd = svd_factorize(params, CFG, 1.0)
    a = forward(params, x, CFG)
    b = forward(params, x, CFG, svd=svd)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-4)


def test_dataset_deterministic_and_shaped():
    x1, y1 = make_dataset(jax.random.PRNGKey(1), CFG, 32)
    x2, y2 = make_dataset(jax.random.PRNGKey(1), CFG, 32)
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    assert x1.shape == (32, 16, 16, 3)
    assert int(jnp.max(y1)) < CFG.num_classes


def test_templates_fixed_across_keys():
    t1 = class_templates(CFG)
    t2 = class_templates(CFG)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))


def test_training_reduces_loss(trained):
    _, losses = trained
    first = sum(losses[:10]) / 10
    last = sum(losses[-10:]) / 10
    assert last < first * 0.7, f"loss {first} -> {last}"


def test_trained_beats_chance_and_orders_variants(trained):
    p, _ = trained
    xt, yt = make_dataset(jax.random.PRNGKey(99), CFG, 256)
    full = accuracy(p, xt, yt, CFG)
    exit0 = accuracy(p, xt, yt, CFG, exit_idx=0)
    chance = 1.0 / CFG.num_classes
    assert full > 4 * chance
    assert full >= exit0, "final exit must not be worse than the earliest"


def test_drift_hurts_accuracy(trained):
    p, _ = trained
    xt, yt = make_dataset(jax.random.PRNGKey(99), CFG, 256)
    clean = accuracy(p, xt, yt, CFG)
    xd = drifted(xt, jax.random.PRNGKey(1), magnitude=1.5)
    shifted = accuracy(p, jnp.asarray(xd), yt, CFG)
    assert shifted <= clean


def test_ensemble_loss_covers_variants(params):
    x, y = make_dataset(jax.random.PRNGKey(2), CFG, 16)
    loss = ensemble_loss(params, x, y, CFG)
    # 3 full-width exits + 2 half-width: ≥ 5 CE terms, each ≥ ~ln(16)·0.5.
    assert float(loss) > 5.0


def test_variant_id_matches_rust_format():
    assert CFG.variant_id() == "w8-16-32_d1-1-1_r100_f0"
    assert CFG.scaled(0.5).variant_id() == "w4-8-16_d1-1-1_r100_f0"


def test_aot_cost_model_consistent():
    from compile.aot import mac_count, param_count

    # Full variant params must equal the actual shipped tensors.
    p = init_params(jax.random.PRNGKey(0), CFG)
    expect = sum(int(np.prod(v.shape)) for k, v in p.items() if not k.startswith(("exit0", "exit1")))
    got = param_count(CFG, 1.0, None, 1.0)
    assert got == expect, f"{got} vs {expect}"
    # Compression monotonicity.
    assert param_count(CFG, 0.5, None, 1.0) < param_count(CFG, 1.0, None, 1.0)
    assert mac_count(CFG, 1.0, 0, 1.0) < mac_count(CFG, 1.0, None, 1.0)
    assert mac_count(CFG, 1.0, None, 0.5) < mac_count(CFG, 1.0, None, 1.0)
