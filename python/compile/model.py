"""Layer-2: the multi-branch early-exit backbone (Sec. III-A1) in JAX.

Mirrors ``rust/src/models/backbone.rs`` layer-for-layer: a stride-2 conv
stem, N stages of 3×3 conv blocks, max-pool between stages, and an exit
head (GAP → FC → softmax) after every stage. Every conv is im2col +
the Layer-1 Pallas fused matmul kernel, so the whole inference graph's
MAC traffic flows through the kernel.

Retraining-free multi-variant support (the paper's elastic inference):

* **η6 / channel scaling** — slimmable training: the loss sums over full-
  and half-width forward passes sharing weight prefixes, so width-scaled
  variants keep accuracy without retraining.
* **η5 / depth scaling** — early exits are trained jointly (ensemble
  training); exiting at branch *i* is a shallower variant.
* **η1 / low-rank** — dense trained weights are truncated-SVD-factorized
  post-training into the kernel's factorized path.

Training runs in the pure-jnp reference path (fast, differentiable);
inference artifacts lower the Pallas path. pytest asserts both paths
agree to float tolerance.
"""

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from .kernels.matmul import factorized_matmul, matmul_fused
from .kernels.ref import factorized_matmul_ref, matmul_fused_ref


@dataclasses.dataclass(frozen=True)
class VariantConfig:
    """Structural hyperparameters; must mirror the Rust BackboneConfig."""

    input_hw: int = 16
    in_channels: int = 3
    num_classes: int = 16
    widths: tuple = (8, 16, 32)
    depths: tuple = (1, 1, 1)
    rank_frac: float = 1.0
    fire: bool = False

    def variant_id(self) -> str:
        w = "-".join(str(x) for x in self.widths)
        d = "-".join(str(x) for x in self.depths)
        return f"w{w}_d{d}_r{round(self.rank_frac * 100)}_f{int(self.fire)}"

    def scaled(self, mult: float) -> "VariantConfig":
        return dataclasses.replace(
            self, widths=tuple(max(1, math.ceil(w * mult)) for w in self.widths)
        )


def im2col(x, stride: int = 1):
    """3×3 SAME patches of NHWC ``x`` → [N, H', W', 9*C].

    Patch axis layout is 9 kernel positions × C channels (position-major),
    so slicing the trailing C block of each position slices input channels
    — what slimmable width scaling needs.
    """
    n, h, w, c = x.shape
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    oh = (h - 1) // stride + 1
    ow = (w - 1) // stride + 1
    cols = []
    for di in range(3):
        for dj in range(3):
            sl = xp[:, di : di + h : stride, dj : dj + w : stride, :]
            cols.append(sl[:, :oh, :ow, :])
    return jnp.concatenate(cols, axis=-1)


def maxpool2(x):
    """2×2/2 max pool, NHWC."""
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def init_params(key, cfg: VariantConfig):
    """He-init full-width parameters. Layout: conv weights are
    [9*in_c, out_c] (position-major patches), biases [out_c]."""
    params = {}

    def conv(key, name, in_c, out_c):
        k1, key = jax.random.split(key)
        fan_in = 9 * in_c
        params[name + "_w"] = jax.random.normal(k1, (fan_in, out_c)) * jnp.sqrt(2.0 / fan_in)
        params[name + "_b"] = jnp.zeros((out_c,))
        return key

    def fc(key, name, in_c, out_c):
        k1, key = jax.random.split(key)
        params[name + "_w"] = jax.random.normal(k1, (in_c, out_c)) * jnp.sqrt(1.0 / in_c)
        params[name + "_b"] = jnp.zeros((out_c,))
        return key

    key = conv(key, "stem", cfg.in_channels, cfg.widths[0])
    prev = cfg.widths[0]
    for si, (wd, dp) in enumerate(zip(cfg.widths, cfg.depths)):
        for bi in range(dp):
            key = conv(key, f"s{si}_b{bi}", prev, wd)
            prev = wd
        key = fc(key, f"exit{si}", wd, cfg.num_classes)
    return params


def _slice_conv(wmat, in_keep, out_keep):
    """Slice a [9*in_c, out_c] conv weight to [9*in_keep, out_keep]."""
    fan, out = wmat.shape
    in_c = fan // 9
    w = wmat.reshape(9, in_c, out)
    return w[:, :in_keep, :out_keep].reshape(9 * in_keep, out_keep)


def forward(params, x, cfg: VariantConfig, width_mult: float = 1.0,
            exit_idx: Optional[int] = None, use_pallas: bool = False,
            svd: Optional[dict] = None):
    """Forward pass to one exit (default: final head). Returns softmax
    probabilities [N, classes].

    * ``width_mult`` < 1 runs the slimmable sub-network (η6);
    * ``exit_idx`` = i exits at branch i (η5);
    * ``svd`` maps conv names → (u, v) factor pairs (η1).
    """
    mm = matmul_fused if use_pallas else matmul_fused_ref
    fmm = factorized_matmul if use_pallas else factorized_matmul_ref
    nstages = len(cfg.widths)
    if exit_idx is None:
        exit_idx = nstages - 1
    widths = [max(1, math.ceil(w * width_mult)) for w in cfg.widths]

    def conv_block(x, name, in_keep, out_keep, stride=1):
        patches = im2col(x, stride)
        n, h, w, f = patches.shape
        flat = patches.reshape(n * h * w, f)
        b = params[name + "_b"][:out_keep]
        if svd is not None and name in svd:
            u, v = svd[name]
            out = fmm(flat, u, v, b, "relu")
            out_keep = v.shape[1]
        else:
            wm = _slice_conv(params[name + "_w"], in_keep, out_keep)
            out = mm(flat, wm, b, "relu")
        return out.reshape(n, h, w, out_keep)

    h = conv_block(x, "stem", cfg.in_channels, widths[0], stride=2)
    prev = widths[0]
    for si in range(exit_idx + 1):
        for bi in range(cfg.depths[si]):
            h = conv_block(h, f"s{si}_b{bi}", prev, widths[si])
            prev = widths[si]
        if si < exit_idx:
            h = maxpool2(h)
    feat = jnp.mean(h, axis=(1, 2))  # adaptive avg pool → [N, w]
    wfc = params[f"exit{exit_idx}_w"][:prev, :]
    bfc = params[f"exit{exit_idx}_b"]
    logits = mm(feat, wfc, bfc, "none")
    return jax.nn.softmax(logits, axis=-1)


def svd_factorize(params, cfg: VariantConfig, rank_frac: float):
    """η1: truncated SVD of every trained conv weight (retraining-free)."""
    svd = {}
    names = ["stem"] + [
        f"s{si}_b{bi}" for si, dp in enumerate(cfg.depths) for bi in range(dp)
    ]
    for name in names:
        wm = params[name + "_w"]
        k, n = wm.shape
        r = max(1, math.ceil(rank_frac * min(k, n)))
        u, s, vt = jnp.linalg.svd(wm, full_matrices=False)
        svd[name] = (u[:, :r] * s[:r], vt[:r, :])
    return svd


# ───────────────────────── synthetic corpus ─────────────────────────────


def class_templates(cfg: VariantConfig, seed: int = 7):
    """The task definition: one random 8×8 texture template per class,
    upsampled to the input size. Fixed seed — train and eval share it.
    Fine (8×8) templates + heavy noise make the task hard enough that the
    variant ensemble shows a real accuracy gradient (full > half-width >
    early-exit > aggressive SVD), mirroring the paper's Table III."""
    coarse = jax.random.normal(
        jax.random.PRNGKey(seed), (cfg.num_classes, 8, 8, cfg.in_channels)
    )
    rep = cfg.input_hw // 8
    return jnp.repeat(jnp.repeat(coarse, rep, axis=1), rep, axis=2)


def make_dataset(key, cfg: VariantConfig, n: int, noise: float = 1.6, seed: int = 7):
    """Synthetic image classification: samples are class templates plus
    Gaussian noise and random brightness. Substitutes the paper's
    Cifar/UbiSound/HAR corpora with the same train→drift→eval structure
    at laptop scale."""
    kc, kn, kb = jax.random.split(key, 3)
    templates = class_templates(cfg, seed)
    labels = jax.random.randint(kc, (n,), 0, cfg.num_classes)
    base = templates[labels]
    noise_v = noise * jax.random.normal(kn, base.shape)
    brightness = 0.1 * jax.random.normal(kb, (n, 1, 1, 1))
    return (base + noise_v + brightness).astype(jnp.float32), labels


def drifted(x, key, magnitude: float = 0.5):
    """Apply a deployment-time distribution shift (Fig. 13's evening
    lighting): contrast scaling + channel tint + extra noise."""
    k1, k2 = jax.random.split(key)
    tint = magnitude * 0.4 * jax.random.normal(k1, (1, 1, 1, x.shape[-1]))
    return (1.0 - 0.3 * magnitude) * x + tint + magnitude * 0.2 * jax.random.normal(k2, x.shape)


# ─────────────────────── ensemble (slimmable) training ───────────────────


def _ce(probs, labels):
    return -jnp.mean(jnp.log(probs[jnp.arange(labels.shape[0]), labels] + 1e-9))


def ensemble_loss(params, x, y, cfg: VariantConfig):
    """Sum of cross-entropies over the variant ensemble (Sec. III-A1's
    'moving retraining ahead into the ensemble training phase'): full
    width at every exit + half width at the last two exits."""
    loss = 0.0
    nstages = len(cfg.widths)
    for e in range(nstages):
        loss = loss + _ce(forward(params, x, cfg, 1.0, e), y)
    for e in (nstages - 2, nstages - 1):
        loss = loss + _ce(forward(params, x, cfg, 0.5, e), y)
    return loss


def train(key, cfg: VariantConfig, steps: int = 300, batch: int = 64, lr: float = 3e-3):
    """Adam on the ensemble loss over the synthetic corpus. Returns the
    trained params and the loss curve."""
    kp, kd = jax.random.split(key)
    params = init_params(kp, cfg)
    x_all, y_all = make_dataset(kd, cfg, 4096)

    # Hand-rolled Adam (no optax in this environment).
    m = jax.tree_util.tree_map(jnp.zeros_like, params)
    v = jax.tree_util.tree_map(jnp.zeros_like, params)
    loss_grad = jax.value_and_grad(lambda p, x, y: ensemble_loss(p, x, y, cfg))

    @jax.jit
    def step(params, m, v, x, y, t):
        loss, g = loss_grad(params, x, y)
        b1, b2, eps = 0.9, 0.999, 1e-8
        m = jax.tree_util.tree_map(lambda a, b: b1 * a + (1 - b1) * b, m, g)
        v = jax.tree_util.tree_map(lambda a, b: b2 * a + (1 - b2) * b * b, v, g)
        mhat = jax.tree_util.tree_map(lambda a: a / (1 - b1**t), m)
        vhat = jax.tree_util.tree_map(lambda a: a / (1 - b2**t), v)
        params = jax.tree_util.tree_map(
            lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mhat, vhat
        )
        return params, m, v, loss

    n = x_all.shape[0]
    losses = []
    for t in range(1, steps + 1):
        lo = ((t - 1) * batch) % (n - batch)
        xb, yb = x_all[lo : lo + batch], y_all[lo : lo + batch]
        params, m, v, loss = step(params, m, v, xb, yb, jnp.asarray(float(t)))
        losses.append(float(loss))
    return params, losses


def accuracy(params, x, y, cfg: VariantConfig, width_mult=1.0, exit_idx=None, svd=None,
             use_pallas: bool = False, batch: int = 256):
    """Top-1 accuracy over a dataset (batched)."""
    n = x.shape[0]
    total = n - n % batch
    if total == 0:
        total, batch = n, n
    correct = 0
    for lo in range(0, total, batch):
        probs = forward(params, x[lo : lo + batch], cfg, width_mult, exit_idx,
                        use_pallas=use_pallas, svd=svd)
        correct += int(jnp.sum(jnp.argmax(probs, axis=-1) == y[lo : lo + batch]))
    return correct / total
