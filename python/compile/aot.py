"""AOT pipeline: train the multi-variant backbone once, lower every
variant × batch size to HLO **text**, and write the artifact manifest the
Rust runtime consumes. Python never runs again after this.

HLO text (NOT ``lowered.compiler_ir(...).serialize()``) is the interchange
format: jax ≥ 0.5 emits HloModuleProtos with 64-bit instruction ids that
the image's xla_extension 0.5.1 rejects; the text parser reassigns ids
(see /opt/xla-example/README.md).

Usage: ``cd python && python -m compile.aot --out ../artifacts``
"""

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .model import (
    VariantConfig,
    accuracy,
    forward,
    make_dataset,
    svd_factorize,
    train,
)

BATCH_SIZES = (1, 8)
EVAL_N = 512
SEED = 0


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is ESSENTIAL: the default elides weight
    # tensors as `constant({...})`, which the HLO text parser silently
    # reads back as zeros — the model would "run" and predict uniformly.
    return comp.as_hlo_text(print_large_constants=True)


def conv_names(cfg: VariantConfig):
    return ["stem"] + [f"s{si}_b{bi}" for si, dp in enumerate(cfg.depths) for bi in range(dp)]


def param_count(cfg: VariantConfig, width_mult: float, exit_idx, rank_frac: float) -> int:
    """Exact parameter count of a variant (weights actually shipped)."""
    import math

    widths = [max(1, math.ceil(w * width_mult)) for w in cfg.widths]
    nstages = len(cfg.widths)
    e = exit_idx if exit_idx is not None else nstages - 1
    total = 0
    prev = cfg.in_channels
    names = [("stem", cfg.in_channels, widths[0])]
    p = widths[0]
    for si in range(e + 1):
        for bi in range(cfg.depths[si]):
            names.append((f"s{si}_b{bi}", p, widths[si]))
            p = widths[si]
    for _, in_c, out_c in names:
        k = 9 * in_c
        if rank_frac < 1.0:
            r = max(1, math.ceil(rank_frac * min(k, out_c)))
            total += k * r + r * out_c + out_c
        else:
            total += k * out_c + out_c
        prev = out_c
    total += p * cfg.num_classes + cfg.num_classes  # exit head
    return total


def mac_count(cfg: VariantConfig, width_mult: float, exit_idx, rank_frac: float) -> int:
    """Exact MAC count of a variant at batch 1."""
    import math

    widths = [max(1, math.ceil(w * width_mult)) for w in cfg.widths]
    nstages = len(cfg.widths)
    e = exit_idx if exit_idx is not None else nstages - 1
    hw = cfg.input_hw // 2  # after stem stride 2
    total = 0

    def conv_macs(in_c, out_c, hw):
        k = 9 * in_c
        if rank_frac < 1.0:
            r = max(1, math.ceil(rank_frac * min(k, out_c)))
            return hw * hw * (k * r + r * out_c)
        return hw * hw * k * out_c

    total += conv_macs(cfg.in_channels, widths[0], hw)
    prev = widths[0]
    for si in range(e + 1):
        for bi in range(cfg.depths[si]):
            total += conv_macs(prev, widths[si], hw)
            prev = widths[si]
        if si < e:
            hw //= 2
    total += prev * cfg.num_classes
    return total


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--quick", action="store_true", help="tiny run for CI smoke")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    cfg = VariantConfig()
    steps = 60 if args.quick else args.steps
    t0 = time.time()
    print(f"[aot] training multi-variant backbone ({steps} steps)...")
    params, losses = train(jax.random.PRNGKey(SEED), cfg, steps=steps)
    print(f"[aot] trained in {time.time() - t0:.1f}s; loss {losses[0]:.3f} -> {losses[-1]:.3f}")

    # Held-out eval set (also shipped to Rust for live accuracy checks).
    xt, yt = make_dataset(jax.random.PRNGKey(99), cfg, EVAL_N)
    np.asarray(xt, np.float32).tofile(os.path.join(args.out, "eval_inputs.bin"))
    np.asarray(yt, np.uint32).tofile(os.path.join(args.out, "eval_labels.bin"))

    svd50 = svd_factorize(params, cfg, 0.5)
    svd75 = svd_factorize(params, cfg, 0.75)

    # The shipped variant menu: η5 (early exits), η6 (half width), η1 (SVD).
    # (id, label, width_mult, exit_idx, svd, rank_frac)
    menu = [
        ("full", "original", 1.0, None, None, 1.0),
        ("exit1", "η5(exit1)", 1.0, 1, None, 1.0),
        ("exit0", "η5(exit0)", 1.0, 0, None, 1.0),
        ("half", "η6(0.5)", 0.5, None, None, 1.0),
        ("half_exit1", "η5+η6", 0.5, 1, None, 1.0),
        ("svd75", "η1(0.75)", 1.0, None, svd75, 0.75),
        ("svd50", "η1(0.5)", 1.0, None, svd50, 0.5),
    ]

    variants = []
    for vid, label, mult, exit_idx, svd, rank in menu:
        acc = accuracy(params, xt, yt, cfg, width_mult=mult, exit_idx=exit_idx, svd=svd)
        files = {}
        for batch in BATCH_SIZES:
            fn = functools.partial(
                forward, params, cfg=cfg, width_mult=mult, exit_idx=exit_idx,
                use_pallas=True, svd=svd,
            )
            spec = jax.ShapeDtypeStruct(
                (batch, cfg.input_hw, cfg.input_hw, cfg.in_channels), jnp.float32
            )
            lowered = jax.jit(fn).lower(spec)
            text = to_hlo_text(lowered)
            fname = f"variant_{vid}_b{batch}.hlo.txt"
            with open(os.path.join(args.out, fname), "w") as f:
                f.write(text)
            files[str(batch)] = fname
        import math

        widths = [max(1, math.ceil(w * mult)) for w in cfg.widths]
        nexits = len(cfg.widths)
        variants.append({
            "id": vid,
            "label": label,
            "files": files,
            "test_acc": acc,
            "params": param_count(cfg, mult, exit_idx, rank),
            "macs": mac_count(cfg, mult, exit_idx, rank),
            "exit": exit_idx if exit_idx is not None else nexits - 1,
            "config": {
                "input_hw": cfg.input_hw,
                "in_channels": cfg.in_channels,
                "num_classes": cfg.num_classes,
                "widths": widths,
                "depths": list(cfg.depths),
                "rank_frac": rank,
                "fire": False,
            },
        })
        print(f"[aot] {vid:<11} acc={acc:.3f} files={list(files.values())}")

    manifest = {
        "format": "crowdhmt-artifacts-v1",
        "task": "synthetic16",
        "num_classes": cfg.num_classes,
        "input_hw": cfg.input_hw,
        "in_channels": cfg.in_channels,
        "batch_sizes": list(BATCH_SIZES),
        "variants": variants,
        "eval": {"inputs": "eval_inputs.bin", "labels": "eval_labels.bin", "count": EVAL_N},
        "loss_curve": losses[:: max(1, len(losses) // 100)],
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote manifest with {len(variants)} variants to {args.out}")


if __name__ == "__main__":
    main()
