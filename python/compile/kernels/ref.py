"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth.

pytest compares every kernel output against these references across a
hypothesis-driven sweep of shapes and dtypes.
"""

import jax.numpy as jnp


def _act(x, act: str):
    if act == "relu":
        return jnp.maximum(x, 0.0)
    if act == "tanh":
        return jnp.tanh(x)
    if act == "none":
        return x
    raise ValueError(f"unknown act '{act}'")


def matmul_fused_ref(x, w, b=None, act: str = "none"):
    """act(x @ w + b) in plain jnp."""
    out = x @ w
    if b is not None:
        out = out + b
    return _act(out, act)


def factorized_matmul_ref(x, u, v, b=None, act: str = "none"):
    """act(x @ u @ v + b) in plain jnp."""
    out = (x @ u) @ v
    if b is not None:
        out = out + b
    return _act(out, act)
