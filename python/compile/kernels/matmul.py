"""Layer-1 Pallas kernels: tiled matmul with fused bias + activation.

This is the compute hot-spot of the multi-branch backbone — every conv
(via im2col) and every FC head lowers to this kernel, so the whole
inference graph's MAC traffic flows through it.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid tiles the
output into (bm × bn) MXU-shaped blocks; the K reduction is the innermost
grid axis, accumulating into the output block resident in VMEM; bias-add
and the activation epilogue are fused into the final K step, so the
intermediate pre-activation tensor never round-trips through HBM —
the same insight the paper's operator-fusion engine exploits, expressed
in BlockSpec instead of threadblocks.

Kernels MUST run with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls (see /opt/xla-example/README.md). Structure
(tiling, fusion, VMEM budget) is still TPU-shaped; EXPERIMENTS.md §Perf
estimates the VMEM footprint and MXU utilization from the BlockSpecs.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default MXU-aligned tile sizes (128 lanes); shrunk automatically for
# small operands.
BM, BN, BK = 128, 128, 128


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref, *, nsteps: int, act: str):
    """One (i, j, k) grid step: o[i,j] += x[i,k] @ w[k,j], epilogue at k end."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=o_ref.dtype
    )

    @pl.when(pl.program_id(2) == nsteps - 1)
    def _epilogue():
        out = o_ref[...] + b_ref[...]
        if act == "relu":
            out = jnp.maximum(out, 0.0)
        elif act == "tanh":
            out = jnp.tanh(out)
        o_ref[...] = out


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("act", "bm", "bn", "bk"))
def matmul_fused(x, w, b=None, act: str = "none", bm: int = BM, bn: int = BN, bk: int = BK):
    """``act(x @ w + b)`` through the Pallas kernel.

    x: [M, K], w: [K, N], b: [N] or None. Operands are zero-padded up to
    tile multiples and the result sliced back — zero rows/cols contribute
    nothing to the reduction.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims {k} vs {k2}"
    if b is None:
        b = jnp.zeros((n,), x.dtype)
    bm = min(bm, max(8, m))
    bn = min(bn, max(8, n))
    bk = min(bk, max(8, k))
    xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
    wp = _pad_to(_pad_to(w, bk, 0), bn, 1)
    bp = _pad_to(b.reshape(1, -1), bn, 1)
    mp, kp = xp.shape
    np_ = wp.shape[1]
    nsteps = kp // bk
    grid = (mp // bm, np_ // bn, nsteps)
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nsteps=nsteps, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n]


def factorized_matmul(x, u, v, b=None, act: str = "none"):
    """η1 (SVD) path: ``act(x @ u @ v + b)`` as two fused-kernel calls.

    ``u: [K, r]``, ``v: [r, N]`` come from a truncated SVD of the trained
    dense weight; rank r < min(K, N) cuts MACs from K·N to r·(K+N).
    """
    h = matmul_fused(x, u, None, "none")
    return matmul_fused(h, v, b, act)


def vmem_bytes(bm: int = BM, bn: int = BN, bk: int = BK, dtype_bytes: int = 4) -> int:
    """VMEM resident per grid step: x, w, bias, and the output accumulator.

    Used by the §Perf analysis — must stay well under the ~16 MiB/core
    VMEM budget of a TPU.
    """
    return dtype_bytes * (bm * bk + bk * bn + bn + bm * bn)
