//! End-to-end validation driver: serve the trained multi-variant backbone
//! through the full stack — a replicated PJRT serving pool, per-worker
//! dynamic batching, and the adaptation loop broadcasting variant
//! switches live as the simulated context degrades (contention → DVFS →
//! memory squeeze → low battery).
//!
//! This is the run recorded in EXPERIMENTS.md §End-to-end: per-phase
//! variant choice, measured accuracy on held-out data, real p50/p99
//! latency and throughput.
//!
//! Run: `make artifacts && cargo run --release --features pjrt --example adaptive_serving`

use std::time::{Duration, Instant};

use crowdhmtware::coordinator::{
    run_cascade, select_variant, BatcherConfig, DispatchPolicy, Executor, PoolConfig,
    ServingPool, Stage, Submission,
};
use crowdhmtware::device::{device, ContextState, ResourceMonitor};
use crowdhmtware::runtime::{Manifest, ModelRuntime};
use crowdhmtware::util::Table;

/// Pool width for the serving phases (each worker owns a PJRT client).
const WORKERS: usize = 4;

/// The context phases of the scenario (per ~80 requests): idle → heavy
/// contention (cache/DVFS) → memory squeeze → low battery.
fn phases() -> Vec<(&'static str, ContextState, f64)> {
    let idle = ContextState::idle();
    let contended = ContextState {
        freq_frac: 0.6,
        competing_procs: 4,
        cache_share: 0.25,
        mem_avail_frac: 0.6,
        ..ContextState::idle()
    };
    let squeezed = ContextState { mem_avail_frac: 0.12, ..contended.clone() };
    let low_battery = ContextState { battery: 0.12, mem_avail_frac: 0.5, ..ContextState::idle() };
    vec![
        ("idle", idle, f64::INFINITY),
        ("contended", contended, f64::INFINITY),
        // Memory squeeze: cap the model footprint hard (16 KB — the
        // synthetic backbone's full variant needs ~33 KB).
        ("mem-squeeze", squeezed, 16.0 * 1024.0),
        ("low-battery", low_battery, f64::INFINITY),
    ]
}

fn main() -> anyhow::Result<()> {
    let Some(dir) = Manifest::default_dir() else {
        eprintln!("no artifacts — run `make artifacts` first");
        std::process::exit(1);
    };
    let manifest = Manifest::load(&dir)?;
    let per = manifest.input_hw * manifest.input_hw * manifest.in_channels;
    let (inputs, labels) = manifest.load_eval()?;
    let variants = manifest.variants.clone();

    // The simulated host device (the "phone" the coordinator runs on).
    let mon = ResourceMonitor::new(device("xiaomi-mi6").unwrap());

    let dir2 = dir.clone();
    let server = ServingPool::spawn(
        move |_worker| Box::new(ModelRuntime::load(dir2.clone()).expect("load")) as Box<dyn Executor>,
        "full",
        PoolConfig {
            workers: WORKERS,
            queue_capacity: 256,
            batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(2) },
            dispatch: DispatchPolicy::LeastQueueDepth,
            ..PoolConfig::default()
        },
    );

    let mut table = Table::new(
        "Adaptive serving (real PJRT execution on the synthetic task)",
        &["phase", "variant", "req", "accuracy", "p50 ms", "p99 ms", "req/s"],
    );
    let per_phase = 80;
    let mut req_i = 0usize;
    for (name, ctx, mem_budget) in phases() {
        // Adaptation tick: profile variants under the live snapshot and
        // switch the server (Sec. III-D's loop, 1 Hz in the paper).
        let snap = mon.sample(&ctx);
        let budget = mem_budget.min(snap.mem_budget_bytes);
        let chosen = select_variant(&variants, &snap, budget).expect("a variant fits");
        // Broadcast the switch; returns once every worker has acked, so
        // every request below is served by the chosen variant.
        server.switch_variant(&chosen);

        // Warmup: the first batch per (worker, variant, batch-size) pays
        // PJRT compilation; measure steady-state serving like the paper
        // does. Enough requests to touch every worker.
        let mut warm = Vec::new();
        for i in 0..9 * WORKERS {
            let idx = i % labels.len();
            let input = inputs[idx * per..(idx + 1) * per].to_vec();
            warm.push(server.submit_with(Submission::new(input)).expect("warmup admitted"));
        }
        for w in warm {
            let _ = w.recv_timeout(Duration::from_secs(120))?;
        }

        let t0 = Instant::now();
        let mut rxs = Vec::new();
        for _ in 0..per_phase {
            let idx = req_i % labels.len();
            req_i += 1;
            let input = inputs[idx * per..(idx + 1) * per].to_vec();
            let rx = server.submit_with(Submission::new(input)).expect("admitted");
            rxs.push((labels[idx], rx));
        }
        let mut correct = 0usize;
        let mut lats = Vec::new();
        for (label, rx) in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(120))?;
            if resp.pred as u32 == label {
                correct += 1;
            }
            lats.push(resp.latency.as_secs_f64());
        }
        let wall = t0.elapsed().as_secs_f64();
        lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
        table.row(&[
            name.to_string(),
            chosen.clone(),
            per_phase.to_string(),
            format!("{:.1}%", 100.0 * correct as f64 / per_phase as f64),
            format!("{:.1}", lats[lats.len() / 2] * 1e3),
            format!("{:.1}", lats[lats.len() * 99 / 100] * 1e3),
            format!("{:.0}", per_phase as f64 / wall),
        ]);
    }
    // The control plane's measured-side view: the hub snapshot the
    // calibrator and AIMD sizer consume each tick (Fig. 6's back-end →
    // front-end feedback), printed before shutdown while workers are live.
    let tel = server.telemetry_snapshot();
    println!(
        "telemetry hub: live_workers={} occupancy={:.2} p50={:.1}ms p95={:.1}ms lanes normal/priority={}/{} variants measured={}",
        tel.live_workers,
        tel.occupancy(),
        tel.p50_s * 1e3,
        tel.p95_s * 1e3,
        tel.lanes[crowdhmtware::telemetry::Lane::Normal.index()].served,
        tel.lanes[crowdhmtware::telemetry::Lane::High.index()].served,
        tel.per_variant.len(),
    );
    let stats = server.shutdown();
    table.print();
    println!(
        "\npool: workers={} served={} batches={} rejected={} switches={} (expect ≥2: squeeze + battery phases force lighter variants)",
        stats.per_worker.len(),
        stats.served(),
        stats.batches(),
        stats.rejected(),
        stats.switches(),
    );
    let occ = stats
        .occupancy()
        .iter()
        .map(|o| format!("{o:.1}"))
        .collect::<Vec<_>>()
        .join("/");
    let merged = stats.merged();
    println!("per-worker mean batch occupancy: {occ}  |  pool p50={:.1}ms p99={:.1}ms", merged.percentile(0.5) * 1e3, merged.percentile(0.99) * 1e3);

    // ── Adaptive early-exit cascade (Sec. III-A1) on real artifacts ────
    // exit0 → exit1 → full: confident inputs answer at shallow branches;
    // the threshold trades average compute against accuracy.
    let mut rt = crowdhmtware::runtime::ModelRuntime::load(dir)?;
    let macs: Vec<f64> = ["exit0", "exit1", "full"]
        .iter()
        .map(|v| rt.manifest.variant(v).unwrap().macs as f64)
        .collect();
    // Incremental stage costs: in the multi-branch network the exits
    // share one backbone pass, so escalating from exit_i to exit_{i+1}
    // only pays the prefix *delta* (our standalone artifacts re-run the
    // prefix — a single-pass multi-head artifact would not; the cost
    // model reports the paper's multi-branch semantics).
    let cost: Vec<f64> =
        vec![macs[0] / macs[2], (macs[1] - macs[0]) / macs[2], (macs[2] - macs[1]) / macs[2]];
    let n = 256usize;
    let mut cascade_table = Table::new(
        "Early-exit cascade: accuracy vs average compute (real PJRT)",
        &["threshold", "accuracy", "avg compute vs full", "answered @exit0/1/full"],
    );
    for th in [0.5f32, 0.8, 0.95] {
        let stages = vec![
            Stage { variant: "exit0".into(), threshold: th },
            Stage { variant: "exit1".into(), threshold: th },
            Stage { variant: "full".into(), threshold: 0.0 },
        ];
        let (res, cs) = run_cascade(&mut rt, &stages, &cost, &inputs[..n * per], n)?;
        let correct = res.iter().zip(labels.iter()).filter(|(r, &l)| r.0 as u32 == l).count();
        let full_cost: f64 = cost.iter().sum();
        cascade_table.row(&[
            format!("{th:.2}"),
            format!("{:.1}%", 100.0 * correct as f64 / n as f64),
            format!("{:.0}%", 100.0 * cs.avg_cost / full_cost),
            format!("{}/{}/{}", cs.answered[0], cs.answered[1], cs.answered[2]),
        ]);
    }
    cascade_table.print();
    Ok(())
}
