//! Regenerates EVERY table and figure of the paper's evaluation in one
//! run (the per-experiment benches do the same individually). Used to
//! produce EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example paper_tables`

use crowdhmtware::experiments as ex;

fn main() {
    let t0 = std::time::Instant::now();
    ex::fig8::table(&ex::fig8::run("raspberrypi-4b")).print();
    ex::fig9::table(&ex::fig9::run()).print();
    ex::table1::table(&ex::table1::run()).print();
    ex::table2::table(&ex::table2::run()).print();
    ex::fig10::table(&ex::fig10::run()).print();
    ex::table3::table(&ex::table3::run()).print();
    ex::fig11::table(&ex::fig11::run()).print();
    ex::table4::table(&ex::table4::run()).print();
    ex::table5::table(&ex::table5::run()).print();
    ex::fig13::table(&ex::fig13::run(6)).print();
    println!("\nall tables generated in {:.1}s", t0.elapsed().as_secs_f64());
}
