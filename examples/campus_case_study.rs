//! The paper's Sec. IV-G case study: a vehicle and a drone (both Jetson
//! Xavier NX) running object classification over a day-long trace with
//! battery drain, memory crunches, and evening distribution drift.
//! Regenerates Fig. 13's strategy-switch timeline and summarizes the
//! e1 → e2 → e3 adaptation events.
//!
//! Run: `cargo run --release --example campus_case_study`

use crowdhmtware::experiments::fig13;

fn main() {
    let log = fig13::run(8);
    fig13::table(&log).print();

    // Summarize the adaptation events.
    let mut events = Vec::new();
    let mut last = String::new();
    for e in &log {
        if e.chosen != last || (e.offloaded && events.last().map(|(_, _, o)| !o).unwrap_or(true)) {
            events.push((e.tick, e.chosen.clone(), e.offloaded));
            last = e.chosen.clone();
        }
    }
    println!("\nadaptation events:");
    for (tick, strategy, offloaded) in &events {
        println!(
            "  tick {:>3}: switch to {}{}",
            tick,
            strategy,
            if *offloaded { " (offloading to drone)" } else { "" }
        );
    }
    println!(
        "\n{} strategy switches across the day (paper: e1 accuracy-focused → e2 offload on memory crunch → e3 energy-saving at 21% battery)",
        events.len()
    );
}
