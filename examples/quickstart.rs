//! Quickstart: load the AOT artifacts, serve a handful of inference
//! requests through the coordinator (router → dynamic batcher → PJRT
//! executor), and print predictions with per-request latency.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use std::time::Duration;

use crowdhmtware::coordinator::{spawn, BatcherConfig, Executor};
use crowdhmtware::runtime::{Manifest, ModelRuntime};

fn main() -> anyhow::Result<()> {
    let Some(dir) = Manifest::default_dir() else {
        eprintln!("no artifacts — run `make artifacts` first");
        std::process::exit(1);
    };
    // Peek at the manifest on the main thread for the workload shape.
    let manifest = Manifest::load(&dir)?;
    println!(
        "task={} classes={} variants={}",
        manifest.task,
        manifest.num_classes,
        manifest.variants.len()
    );
    let per = manifest.input_hw * manifest.input_hw * manifest.in_channels;
    let eval = manifest.load_eval()?;
    let (inputs, labels) = eval;

    // The PJRT runtime is constructed *inside* the worker thread.
    let mut server = spawn(
        move || Box::new(ModelRuntime::load(dir).expect("load artifacts")) as Box<dyn Executor>,
        "full".to_string(),
        BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(5) },
    );

    // Submit 32 requests from the held-out eval set.
    let n = 32;
    let mut rxs = Vec::new();
    for i in 0..n {
        let row = inputs[i * per..(i + 1) * per].to_vec();
        rxs.push((labels[i], server.submit(row)));
    }
    let mut correct = 0;
    for (label, rx) in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(60))?;
        if resp.pred as u32 == label {
            correct += 1;
        }
        println!(
            "req {:>3}: pred={:<2} label={:<2} conf={:.2} latency={:?} [{}]",
            resp.id, resp.pred, label, resp.confidence, resp.latency, resp.variant
        );
    }
    let stats = server.shutdown();
    println!(
        "\naccuracy {}/{} = {:.1}%  |  batches={} mean_batch={:.1}  p50={:.1}ms p99={:.1}ms",
        correct,
        n,
        100.0 * correct as f64 / n as f64,
        stats.batches,
        stats.mean_batch_size(),
        stats.percentile(0.5) * 1e3,
        stats.percentile(0.99) * 1e3,
    );
    Ok(())
}
