//! Quickstart: load the AOT artifacts, serve a handful of inference
//! requests through the coordinator (router → per-worker dynamic batcher
//! → PJRT executor), and print predictions with per-request latency.
//!
//! Run: `make artifacts && cargo run --release --features pjrt --example quickstart`

use std::time::Duration;

use crowdhmtware::coordinator::{BatcherConfig, Executor, PoolConfig, ServingPool, Submission};
use crowdhmtware::runtime::{Manifest, ModelRuntime};

fn main() -> anyhow::Result<()> {
    let Some(dir) = Manifest::default_dir() else {
        eprintln!("no artifacts — run `make artifacts` first");
        std::process::exit(1);
    };
    // Peek at the manifest on the main thread for the workload shape.
    let manifest = Manifest::load(&dir)?;
    println!(
        "task={} classes={} variants={}",
        manifest.task,
        manifest.num_classes,
        manifest.variants.len()
    );
    let per = manifest.input_hw * manifest.input_hw * manifest.in_channels;
    let eval = manifest.load_eval()?;
    let (inputs, labels) = eval;

    // A two-worker pool; each PJRT runtime is constructed *inside* its
    // worker thread (clients are thread-affine).
    let server = ServingPool::spawn(
        move |_worker| Box::new(ModelRuntime::load(dir.clone()).expect("load artifacts")) as Box<dyn Executor>,
        "full",
        PoolConfig {
            workers: 2,
            queue_capacity: 64,
            batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(5) },
            ..PoolConfig::default()
        },
    );

    // Submit 32 requests from the held-out eval set.
    let n = 32;
    let mut rxs = Vec::new();
    for i in 0..n {
        let row = inputs[i * per..(i + 1) * per].to_vec();
        rxs.push((labels[i], server.submit_with(Submission::new(row)).expect("admitted")));
    }
    let mut correct = 0;
    for (label, rx) in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(60))?;
        if resp.pred as u32 == label {
            correct += 1;
        }
        println!(
            "req {:>3}: pred={:<2} label={:<2} conf={:.2} latency={:?} [{}]",
            resp.id, resp.pred, label, resp.confidence, resp.latency, resp.variant
        );
    }
    let stats = server.shutdown();
    let merged = stats.merged();
    println!(
        "\naccuracy {}/{} = {:.1}%  |  workers={} batches={} mean_batch={:.1}  p50={:.1}ms p99={:.1}ms",
        correct,
        n,
        100.0 * correct as f64 / n as f64,
        stats.per_worker.len(),
        stats.batches(),
        merged.mean_batch_size(),
        merged.percentile(0.5) * 1e3,
        merged.percentile(0.99) * 1e3,
    );
    Ok(())
}
